//! A small dependency-free scoped thread pool for the native backend's
//! batch kernels.
//!
//! Design constraints (see ISSUE 3 / the "Batch-native policy inference"
//! ROADMAP item):
//!
//! * **std only, persistent workers** — workers are spawned once (lazily,
//!   on first use of [`NativePool::global`]) and parked on a condvar, so
//!   per-batch dispatch cost is one lock + one notify, not a thread spawn.
//! * **Scoped** — [`NativePool::run`] accepts closures that borrow stack
//!   data (GEMM row-panels are `split_at_mut` slices of the caller's
//!   output buffer).  Soundness: `run` does not return until every
//!   submitted job has finished executing, enforced by a per-scope
//!   completion latch; the lifetime of the closures is erased only for
//!   the duration of that call.
//! * **Nested-safe** — the calling thread participates: it drains the
//!   shared queue before blocking on its latch, so a job that itself
//!   calls `run` (nested parallelism) always makes progress even when
//!   every worker is busy.  Zero-job scopes return immediately.
//! * **Deterministic** — the pool only distributes *disjoint* work items;
//!   all kernels in [`super::gemm`] shard over output rows so every
//!   output element is produced by exactly one task with a fixed
//!   reduction order.  Results are bit-identical for any thread count
//!   (covered by `rust/tests/prop_kernels.rs`).
//!
//! Thread count: `SF_NATIVE_THREADS` overrides; the default is
//! `available_parallelism` capped at [`MAX_DEFAULT_THREADS`].  A value of
//! 1 (or a 1-core machine) makes every `run` execute inline on the
//! caller — no workers, no locks on the hot path.

use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::{thread, Arc, Condvar, Mutex};
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::OnceLock;

/// Default cap on the implicit pool size: the batch kernels saturate
/// memory bandwidth well before this many cores help.
pub const MAX_DEFAULT_THREADS: usize = 16;

/// A borrowed job: runs once, may capture references into the caller's
/// stack frame (valid for the duration of the `run` call that submitted
/// it).
pub type Job<'scope> = Box<dyn FnOnce() + Send + 'scope>;

/// One stage of [`NativePool::run_waves`]: given exclusive access to the
/// shared context, produce the stage's jobs.  The builder runs on the
/// calling thread, strictly after every earlier wave has drained, so the
/// jobs it returns may borrow state an earlier wave mutated.
pub type Wave<'env, C> = Box<dyn for<'a> FnOnce(&'a mut C) -> Vec<Job<'a>> + 'env>;

struct Task {
    job: Box<dyn FnOnce() + Send + 'static>,
    scope: Arc<ScopeState>,
    /// Enqueue timestamp (`obs` clock, ns) when pool sampling is armed
    /// (`crate::obs::set_pool_sampling`); 0 = unsampled.  Feeds the
    /// task wait/run histograms in `crate::obs::pool_stats`.
    t_enq: u64,
}

/// Run one task, recording wait/run time into the process-global pool
/// histograms when it was stamped, and wrapping execution in a
/// `pool.task` trace span (one relaxed load when tracing is off).
fn exec_task(task: Task) {
    let run0 = if task.t_enq != 0 {
        let now = crate::obs::clock::now_ns();
        crate::obs::pool_stats().task_wait_ns.record(now.saturating_sub(task.t_enq));
        now
    } else {
        0
    };
    let panicked = {
        let _sp = crate::obs::trace::span("pool.task");
        catch_unwind(AssertUnwindSafe(task.job)).is_err()
    };
    if run0 != 0 {
        crate::obs::pool_stats()
            .task_run_ns
            .record(crate::obs::clock::now_ns().saturating_sub(run0));
    }
    task.scope.complete(panicked);
}

struct ScopeState {
    state: Mutex<ScopeProgress>,
    done: Condvar,
}

struct ScopeProgress {
    pending: usize,
    panicked: bool,
}

impl ScopeState {
    fn new(pending: usize) -> ScopeState {
        ScopeState {
            state: Mutex::new(ScopeProgress { pending, panicked: false }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.pending -= 1;
        st.panicked |= panicked;
        if st.pending == 0 {
            drop(st);
            self.done.notify_all();
        }
    }

    /// Block until every job of this scope has completed; returns whether
    /// any of them panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.pending > 0 {
            st = self.done.wait(st).unwrap();
        }
        st.panicked
    }
}

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    work: Condvar,
    shutdown: AtomicBool,
}

/// The scoped thread pool.  One global instance serves the whole process
/// ([`NativePool::global`]); tests construct private instances to pin the
/// thread count.
pub struct NativePool {
    shared: Arc<Shared>,
    /// Total compute threads including the caller (workers = threads - 1).
    threads: usize,
}

impl NativePool {
    /// A pool with `threads` total compute threads (the caller counts as
    /// one; `threads - 1` workers are spawned).  `threads == 0` is
    /// treated as 1.
    pub fn new(threads: usize) -> NativePool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        for i in 1..threads {
            let sh = Arc::clone(&shared);
            // Detached: workers exit when `shutdown` flips (see Drop).
            // Indexed names so `perf`/`top`/TSan reports are attributable.
            let _ = thread::spawn_named(&format!("sf-pool-{i}"), move || {
                // Pin to the reserved set when a placement plan installed
                // one before this worker spawned (no-op otherwise).
                crate::runtime::placement::pin_native_pool_thread();
                worker_loop(sh)
            });
        }
        NativePool { shared, threads }
    }

    /// The process-wide pool, created on first use.  Size:
    /// `SF_NATIVE_THREADS` if set, else `available_parallelism` capped at
    /// [`MAX_DEFAULT_THREADS`].
    pub fn global() -> &'static NativePool {
        static POOL: OnceLock<NativePool> = OnceLock::new();
        POOL.get_or_init(|| NativePool::new(default_threads()))
    }

    /// Total compute threads (callers of `run` count as one).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute every job to completion, using the workers plus the calling
    /// thread.  Returns only when all jobs have finished (this is what
    /// makes borrowing caller data sound).  Panics (after all jobs have
    /// settled) if any job panicked.
    pub fn run<'scope>(&self, jobs: Vec<Job<'scope>>) {
        if jobs.is_empty() {
            return;
        }
        if self.threads <= 1 || jobs.len() == 1 {
            // Inline fast path: deliberately uninstrumented — no queueing
            // means "task wait" has no meaning here, and single-job scopes
            // are too frequent/short to be worth a histogram record.
            let mut panicked = false;
            for job in jobs {
                panicked |= catch_unwind(AssertUnwindSafe(job)).is_err();
            }
            if panicked {
                panic!("native pool: a parallel task panicked");
            }
            return;
        }
        let scope = Arc::new(ScopeState::new(jobs.len()));
        let t_enq = if crate::obs::pool_sampling() {
            crate::obs::clock::now_ns()
        } else {
            0
        };
        {
            let mut q = self.shared.queue.lock().unwrap();
            for job in jobs {
                // SAFETY: `run` blocks on `scope.wait()` until every job
                // has executed, so the borrows captured by `job` outlive
                // its execution; the 'static erasure never escapes this
                // call.
                let job: Box<dyn FnOnce() + Send + 'static> =
                    unsafe { std::mem::transmute(job) };
                q.push_back(Task { job, scope: Arc::clone(&scope), t_enq });
            }
        }
        self.shared.work.notify_all();
        // Help drain the queue (any scope's tasks — executing a sibling
        // scope's work is harmless and guarantees progress under nesting),
        // then wait for stragglers running on other threads.  The lock is
        // released at each `let` statement's end, never held across a job.
        loop {
            let task = self.shared.queue.lock().unwrap().pop_front();
            let Some(t) = task else { break };
            exec_task(t);
        }
        if scope.wait() {
            panic!("native pool: a parallel task panicked");
        }
    }

    /// Run a sequence of barriered waves over one shared context.
    ///
    /// Each wave builder is invoked only after every job of every earlier
    /// wave has completed, and receives exclusive access to `ctx` to build
    /// its job list.  This is what lets a later wave *read* buffers an
    /// earlier wave *wrote* without overlapping borrows: the context
    /// reborrows are sequenced by the completion barrier of [`run`]
    /// (which is also the happens-before edge — every write of wave `i`
    /// is visible to wave `i + 1`).  Used by the batched raycast renderer
    /// (column-strip raycast, then transpose of those columns).
    ///
    /// [`run`]: NativePool::run
    pub fn run_waves<C>(&self, ctx: &mut C, waves: Vec<Wave<'_, C>>) {
        for wave in waves {
            let jobs = wave(ctx);
            self.run(jobs);
        }
    }

    /// Convenience: split `data` into `chunk_len`-sized pieces and run
    /// `f(chunk_index, chunk)` on each in parallel.  Chunks are disjoint
    /// `&mut` slices, so `f` may write freely.
    pub fn par_chunks_mut<T, F>(&self, data: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let chunk_len = chunk_len.max(1);
        if data.len() <= chunk_len || self.threads <= 1 {
            for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
                f(ci, chunk);
            }
            return;
        }
        let f = &f;
        let mut jobs: Vec<Job<'_>> = Vec::with_capacity(data.len() / chunk_len + 1);
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            jobs.push(Box::new(move || f(ci, chunk)));
        }
        self.run(jobs);
    }

    /// Rows-per-task heuristic for sharding `rows` work items: about two
    /// tasks per thread (load balancing) with a floor of `min_rows` so
    /// tiny problems stay single-task.  Only affects *partitioning* —
    /// never the per-row computation — so results are thread-count
    /// independent.
    pub fn rows_per_task(&self, rows: usize, min_rows: usize) -> usize {
        let tasks = self.threads * 2;
        rows.div_ceil(tasks).max(min_rows).max(1)
    }
}

impl Drop for NativePool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work.notify_all();
        // Workers exit on their own; handles are detached (the global pool
        // lives for the process anyway, and test pools just need the
        // threads to stop waiting).
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    return;
                }
                q = shared.work.wait(q).unwrap();
            }
        };
        exec_task(task);
    }
}

/// `SF_NATIVE_THREADS` override, else `available_parallelism` capped.
/// An *invalid* override is a hard startup error — the old silent
/// fallback meant a typo like `SF_NATIVE_THREADS=4x` quietly benchmarked
/// the default thread count.
pub fn default_threads() -> usize {
    match parse_threads_env(std::env::var("SF_NATIVE_THREADS").ok().as_deref()) {
        Ok(Some(n)) => n,
        Ok(None) => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(MAX_DEFAULT_THREADS),
        Err(msg) => panic!("{msg}"),
    }
}

/// Parse the `SF_NATIVE_THREADS` value (`None` = unset).  Split out pure
/// so the error cases are unit-testable without mutating process env.
pub fn parse_threads_env(v: Option<&str>) -> Result<Option<usize>, String> {
    let Some(s) = v else { return Ok(None) };
    match s.trim().parse::<usize>() {
        Ok(0) => Err(
            "SF_NATIVE_THREADS must be a positive integer, got 0 \
             (unset it to use all cores)"
                .into(),
        ),
        Ok(n) => Ok(Some(n)),
        Err(_) => Err(format!(
            "invalid SF_NATIVE_THREADS '{s}': expected a positive integer \
             (unset it to use all cores)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn zero_jobs_and_empty_chunks_return_immediately() {
        let pool = NativePool::new(3);
        pool.run(Vec::new());
        let mut empty: [u32; 0] = [];
        pool.par_chunks_mut(&mut empty, 4, |_, _| {});
    }

    #[test]
    fn runs_every_job_exactly_once() {
        let pool = NativePool::new(4);
        let counter = AtomicUsize::new(0);
        let mut jobs: Vec<Job<'_>> = Vec::new();
        for _ in 0..100 {
            jobs.push(Box::new(|| {
                counter.fetch_add(1, Ordering::Relaxed);
            }));
        }
        pool.run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn chunks_write_disjoint_slices() {
        let pool = NativePool::new(3);
        let mut data = vec![0u32; 1000];
        pool.par_chunks_mut(&mut data, 7, |ci, chunk| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (ci * 7 + j) as u32;
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn nested_scopes_do_not_deadlock() {
        // Every outer job spawns an inner scope on the same (small) pool;
        // caller participation guarantees progress.
        let pool = Arc::new(NativePool::new(2));
        let counter = Arc::new(AtomicUsize::new(0));
        let mut jobs: Vec<Job<'_>> = Vec::new();
        for _ in 0..8 {
            let pool2 = Arc::clone(&pool);
            let c2 = Arc::clone(&counter);
            jobs.push(Box::new(move || {
                let mut inner: Vec<Job<'_>> = Vec::new();
                for _ in 0..4 {
                    let c3 = Arc::clone(&c2);
                    inner.push(Box::new(move || {
                        c3.fetch_add(1, Ordering::Relaxed);
                    }));
                }
                pool2.run(inner);
            }));
        }
        pool.run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn waves_are_barriered_in_order() {
        // Wave 2 reads what wave 1 wrote: only sound because run_waves
        // drains wave 1 completely before building wave 2's jobs.
        struct Ctx {
            src: Vec<u64>,
            sums: Vec<u64>,
        }
        let pool = NativePool::new(3);
        let mut ctx = Ctx { src: vec![0; 64], sums: vec![0; 4] };
        let fill: Wave<'_, Ctx> = Box::new(|c| {
            let mut jobs: Vec<Job<'_>> = Vec::new();
            for (ci, chunk) in c.src.chunks_mut(16).enumerate() {
                jobs.push(Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (ci * 16 + j) as u64;
                    }
                }));
            }
            jobs
        });
        let reduce: Wave<'_, Ctx> = Box::new(|c| {
            let src = &c.src[..];
            let mut jobs: Vec<Job<'_>> = Vec::new();
            for (ci, slot) in c.sums.iter_mut().enumerate() {
                jobs.push(Box::new(move || {
                    *slot = src[ci * 16..(ci + 1) * 16].iter().sum();
                }));
            }
            jobs
        });
        pool.run_waves(&mut ctx, vec![fill, reduce]);
        assert_eq!(ctx.sums.iter().sum::<u64>(), (0..64).sum::<u64>());
        assert_eq!(ctx.sums[0], (0..16).sum::<u64>());
    }

    #[test]
    #[should_panic(expected = "a parallel task panicked")]
    fn job_panic_propagates_without_deadlock() {
        let pool = NativePool::new(3);
        let mut jobs: Vec<Job<'_>> = Vec::new();
        for i in 0..16 {
            jobs.push(Box::new(move || {
                if i == 7 {
                    panic!("boom");
                }
            }));
        }
        pool.run(jobs);
    }

    #[test]
    fn worker_threads_are_named() {
        // 3 total threads = caller + 2 spawned workers.  A 3-way barrier
        // inside the jobs forces all three to run one job concurrently, so
        // both workers must participate and report their thread names.
        let pool = NativePool::new(3);
        let barrier = std::sync::Barrier::new(3);
        let names = std::sync::Mutex::new(Vec::<Option<String>>::new());
        let mut jobs: Vec<Job<'_>> = Vec::new();
        for _ in 0..3 {
            let (b, n) = (&barrier, &names);
            jobs.push(Box::new(move || {
                b.wait();
                n.lock()
                    .unwrap()
                    .push(std::thread::current().name().map(|s| s.to_string()));
            }));
        }
        pool.run(jobs);
        let names = names.into_inner().unwrap();
        let mut workers: Vec<&str> = names
            .iter()
            .filter_map(|n| n.as_deref())
            .filter(|n| n.starts_with("sf-pool-"))
            .collect();
        workers.sort_unstable();
        assert_eq!(workers, vec!["sf-pool-1", "sf-pool-2"], "all names: {names:?}");
    }

    #[test]
    fn invalid_thread_override_is_a_hard_error() {
        // Regression: these used to fall back silently to the default.
        assert!(parse_threads_env(Some("4x")).is_err());
        assert!(parse_threads_env(Some("")).is_err());
        assert!(parse_threads_env(Some("-2")).is_err());
        assert!(parse_threads_env(Some("0")).is_err());
        assert_eq!(parse_threads_env(Some(" 8 ")), Ok(Some(8)));
        assert_eq!(parse_threads_env(None), Ok(None));
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = NativePool::new(1);
        let mut sum = 0u64;
        {
            let sum_ref = &mut sum;
            pool.run(vec![Box::new(move || {
                *sum_ref = 42;
            }) as Job<'_>]);
        }
        assert_eq!(sum, 42);
    }
}
