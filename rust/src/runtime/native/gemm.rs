//! Batch-level f32 kernels for the native backend: a cache-blocked,
//! autovectorizable GEMM plus im2col/col2im packing and the batched GRU
//! gate math.  These replace the per-row scalar loops in [`super::ops`]
//! on the policy-inference and train-step hot paths; `ops.rs` stays as
//! the reference implementation that the property tests in
//! `rust/tests/prop_kernels.rs` compare against.
//!
//! ## Determinism contract
//!
//! Every kernel here shards work over *output rows* only: each output
//! element is produced by exactly one task, and its reduction runs in a
//! fixed index order (ascending `k`, regardless of the `KC`/`MR`
//! blocking or the number of pool threads).  Results are therefore
//! bit-identical for any `SF_NATIVE_THREADS` value — and, because the
//! inner loops mirror the scalar reference's accumulation order (zero
//! padding contributes exact `+0.0` no-ops), they match `ops.rs` to
//! within float-reassociation noise (the property tests assert 1e-5
//! relative).
//!
//! ## Why this layout
//!
//! The micro-kernel keeps the innermost dimension (`n`, output
//! channels/features) contiguous in both `B` and `C`, so LLVM
//! autovectorizes the fused multiply-add over `n`; the `MR` row panel
//! amortizes each `B`-row load across several output rows, and the `KC`
//! block keeps the streamed `B` panel cache-resident.  There is
//! deliberately no `unsafe` and no architecture-specific code: the same
//! source vectorizes on any target.  The scalar reference's `v == 0.0`
//! skip branch is deliberately absent — it defeated vectorization for a
//! ~2x-at-best sparsity win.
//!
//! ## Explicit SIMD (`--features simd`, nightly)
//!
//! With the `simd` cargo feature the innermost fused multiply-add row
//! runs through a `std::simd::f32x8` micro-kernel ([`fma_row`]).  Each
//! `C` element still receives exactly one `mul` followed by one `add`
//! per `k` step, in the same ascending-`k` order, and `std::simd`
//! lane ops are strict IEEE (no FMA contraction) — so the SIMD path is
//! *bit-identical* to the scalar path by construction; the property
//! tests assert exact equality.  [`set_simd_enabled`] is a runtime
//! kill-switch so benchmarks can A/B scalar vs SIMD in one process;
//! the default build (no feature) compiles the scalar path only.

use std::sync::atomic::{AtomicBool, Ordering};

use super::ops::{sigmoid, ConvGeom};
use super::pool::NativePool;

/// Runtime kill-switch for the explicit-SIMD micro-kernel (stored
/// inverted so the static's `false` default means "on when compiled
/// in").  Only consulted once per GEMM block, never in the inner loop.
static SIMD_OFF: AtomicBool = AtomicBool::new(false);

/// Enable/disable the `f32x8` micro-kernel at runtime (benchmark A/B
/// and the bit-identity property tests).  No-op without
/// `--features simd`.
pub fn set_simd_enabled(on: bool) {
    SIMD_OFF.store(!on, Ordering::Relaxed);
}

/// True when the explicit-SIMD micro-kernel is compiled in *and* not
/// disabled via [`set_simd_enabled`].
pub fn simd_enabled() -> bool {
    cfg!(feature = "simd") && !SIMD_OFF.load(Ordering::Relaxed)
}

/// `c_row[j] += av * b_row[j]` — the innermost GEMM row, dispatched
/// once per block (`use_simd` is hoisted out of the panel loops).
#[inline(always)]
fn fma_row(use_simd: bool, c_row: &mut [f32], av: f32, b_row: &[f32]) {
    #[cfg(feature = "simd")]
    if use_simd {
        return fma_row_simd(c_row, av, b_row);
    }
    #[cfg(not(feature = "simd"))]
    let _ = use_simd;
    for (cv, &bv) in c_row.iter_mut().zip(b_row) {
        *cv += av * bv;
    }
}

/// `f32x8` lane version of [`fma_row`].  Per element this is the same
/// `mul` + `add` pair as the scalar loop (elements are independent
/// across `n`), so results are bit-identical.
#[cfg(feature = "simd")]
fn fma_row_simd(c_row: &mut [f32], av: f32, b_row: &[f32]) {
    use std::simd::f32x8;
    const L: usize = 8;
    let vec_len = (c_row.len() / L) * L;
    let (c_vec, c_tail) = c_row.split_at_mut(vec_len);
    let (b_vec, b_tail) = b_row.split_at(vec_len);
    let avv = f32x8::splat(av);
    for (cc, bb) in c_vec.chunks_exact_mut(L).zip(b_vec.chunks_exact(L)) {
        let c = f32x8::from_slice(cc);
        let b = f32x8::from_slice(bb);
        (c + avv * b).copy_to_slice(cc);
    }
    for (cv, &bv) in c_tail.iter_mut().zip(b_tail) {
        *cv += av * bv;
    }
}

/// Row-panel height of the micro-kernel: each loaded `B` row is applied
/// to this many `A` rows / `C` rows.
const MR: usize = 4;

/// K-dimension block size: one `KC x n` panel of `B` is streamed per
/// block and stays cache-resident across the row panels.
const KC: usize = 256;

// ---------------------------------------------------------------------------
// GEMM
// ---------------------------------------------------------------------------

/// `C[m,n] = A[m,k] @ B[k,n] (+ bias)` — or `C += A @ B` when
/// `accumulate` (bias must be `None` then).  All matrices row-major.
/// Sharded over `C` row panels on `pool`.
// BLAS-style signature: the dims/lds are the interface, same as sgemm's.
#[allow(clippy::too_many_arguments)]
pub fn gemm_nn(
    pool: &NativePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    accumulate: bool,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if let Some(bi) = bias {
        debug_assert_eq!(bi.len(), n);
    }
    debug_assert!(!(accumulate && bias.is_some()), "bias with accumulate");
    if m == 0 || n == 0 {
        return;
    }
    let rows_per = pool.rows_per_task(m, MR.max(8192 / n.max(1)));
    pool.par_chunks_mut(c, rows_per * n, |ci, c_chunk| {
        nn_block(a, b, bias, k, n, ci * rows_per, c_chunk, accumulate);
    });
}

/// Compute one panel of `C` rows (`r0..r0 + c_chunk.len()/n`).
#[allow(clippy::too_many_arguments)] // kernel inner loop, mirrors gemm_nn
fn nn_block(
    a: &[f32],
    b: &[f32],
    bias: Option<&[f32]>,
    k: usize,
    n: usize,
    r0: usize,
    c_chunk: &mut [f32],
    accumulate: bool,
) {
    let rows = c_chunk.len() / n;
    let use_simd = simd_enabled();
    if !accumulate {
        match bias {
            Some(bias) => {
                for row in c_chunk.chunks_exact_mut(n) {
                    row.copy_from_slice(bias);
                }
            }
            None => c_chunk.iter_mut().for_each(|v| *v = 0.0),
        }
    }
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        let mut i = 0;
        while i < rows {
            let ir = MR.min(rows - i);
            let c_panel = &mut c_chunk[i * n..(i + ir) * n];
            for kk in 0..kb {
                let b_row = &b[(k0 + kk) * n..][..n];
                for r in 0..ir {
                    let av = a[(r0 + i + r) * k + k0 + kk];
                    fma_row(use_simd, &mut c_panel[r * n..][..n], av, b_row);
                }
            }
            i += ir;
        }
        k0 += kb;
    }
}

/// `C[k,n] += A[m,k]^T @ B[m,n]` — the parameter-gradient GEMM
/// (`dW += X^T @ dY`).  Always accumulates.  Sharded over `C` row
/// panels; every task scans rows `0..m` in ascending order, so each
/// `C` element's reduction order matches the scalar reference.
pub fn gemm_tn(
    pool: &NativePool,
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    if k == 0 || n == 0 {
        return;
    }
    let rows_per = pool.rows_per_task(k, MR.max(4096 / n.max(1)));
    pool.par_chunks_mut(c, rows_per * n, |ci, c_chunk| {
        tn_block(a, b, m, k, n, ci * rows_per, c_chunk);
    });
}

fn tn_block(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, kk0: usize, c_chunk: &mut [f32]) {
    let kc = c_chunk.len() / n;
    let use_simd = simd_enabled();
    for i in 0..m {
        let a_row = &a[i * k..][..k];
        let b_row = &b[i * n..][..n];
        for kk in 0..kc {
            let av = a_row[kk0 + kk];
            fma_row(use_simd, &mut c_chunk[kk * n..][..n], av, b_row);
        }
    }
}

/// `dst[cols, rows] = src[rows, cols]^T`.  Used to pre-transpose weight
/// matrices once per program call so input-gradient GEMMs
/// (`dX = dY @ W^T`) run through the vector-friendly [`gemm_nn`] path.
pub fn transpose(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    debug_assert_eq!(src.len(), rows * cols);
    debug_assert_eq!(dst.len(), rows * cols);
    for r in 0..rows {
        let s_row = &src[r * cols..][..cols];
        for (cc, &v) in s_row.iter().enumerate() {
            dst[cc * rows + r] = v;
        }
    }
}

/// `out[n] += sum_rows A[m,n]` — bias gradients.  Row-ascending order
/// (matches the scalar reference's per-sample accumulation).
pub fn add_colsum(m: usize, n: usize, a: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(out.len(), n);
    for i in 0..m {
        let a_row = &a[i * n..][..n];
        for (o, &v) in out.iter_mut().zip(a_row) {
            *o += v;
        }
    }
}

/// In-place ReLU over a large batch buffer, sharded on the pool.
pub fn relu_batch(pool: &NativePool, xs: &mut [f32]) {
    let chunk = pool.rows_per_task(xs.len(), 1 << 15);
    pool.par_chunks_mut(xs, chunk, |_, part| {
        for x in part.iter_mut() {
            if *x < 0.0 {
                *x = 0.0;
            }
        }
    });
}

// ---------------------------------------------------------------------------
// im2col / col2im
// ---------------------------------------------------------------------------

/// Elements per im2col row: the flattened receptive field, in the same
/// `(ky, kx, ci)` order the HWIO weight tensor flattens to.
pub fn im2col_row_len(g: &ConvGeom) -> usize {
    g.k * g.k * g.c_in
}

/// Pack `nb` images (each `(H,W,Ci)` row-major, concatenated) into the
/// im2col matrix `cols[nb*h_out*w_out, k*k*ci]`; out-of-bounds taps are
/// zero-filled (SAME padding, including the asymmetric split).  Sharded
/// per image on the pool.
pub fn im2col(pool: &NativePool, g: &ConvGeom, nb: usize, inp: &[f32], cols: &mut [f32]) {
    let krow = im2col_row_len(g);
    let img_len = g.h_out * g.w_out * krow;
    debug_assert_eq!(inp.len(), nb * g.in_len());
    debug_assert_eq!(cols.len(), nb * img_len);
    let per_task = pool.rows_per_task(nb, 1);
    pool.par_chunks_mut(cols, per_task * img_len, |ci, chunk| {
        for (bi, img_cols) in chunk.chunks_exact_mut(img_len).enumerate() {
            let b = ci * per_task + bi;
            im2col_image(g, &inp[b * g.in_len()..][..g.in_len()], img_cols);
        }
    });
}

fn im2col_image(g: &ConvGeom, img: &[f32], cols: &mut [f32]) {
    let (k, ci) = (g.k, g.c_in);
    let krow = k * k * ci;
    for ho in 0..g.h_out {
        for wo in 0..g.w_out {
            let row = &mut cols[(ho * g.w_out + wo) * krow..][..krow];
            let x0 = (wo * g.stride) as isize - g.pad_left as isize;
            // kx sub-range whose input column lands inside [0, w_in).
            let kx_lo = ((-x0).max(0) as usize).min(k);
            let kx_hi = ((g.w_in as isize - x0).max(0) as usize).min(k);
            for ky in 0..k {
                let y = (ho * g.stride + ky) as isize - g.pad_top as isize;
                let dst = &mut row[ky * k * ci..][..k * ci];
                if y < 0 || y >= g.h_in as isize || kx_lo >= kx_hi {
                    dst.iter_mut().for_each(|v| *v = 0.0);
                    continue;
                }
                dst[..kx_lo * ci].iter_mut().for_each(|v| *v = 0.0);
                dst[kx_hi * ci..].iter_mut().for_each(|v| *v = 0.0);
                // x0 + kx_lo >= 0 by construction of kx_lo.
                let px = (y as usize * g.w_in) as isize + x0 + kx_lo as isize;
                let src0 = px as usize * ci;
                dst[kx_lo * ci..kx_hi * ci]
                    .copy_from_slice(&img[src0..src0 + (kx_hi - kx_lo) * ci]);
            }
        }
    }
}

/// Scatter-add the packed column gradient back into image space:
/// `d_inp[nb images] += col2im(d_cols)`.  The caller zeroes `d_inp`
/// first.  Sharded per image (disjoint image slices).
pub fn col2im_add(pool: &NativePool, g: &ConvGeom, nb: usize, d_cols: &[f32], d_inp: &mut [f32]) {
    let krow = im2col_row_len(g);
    let img_len = g.h_out * g.w_out * krow;
    debug_assert_eq!(d_cols.len(), nb * img_len);
    debug_assert_eq!(d_inp.len(), nb * g.in_len());
    let per_task = pool.rows_per_task(nb, 1);
    pool.par_chunks_mut(d_inp, per_task * g.in_len(), |ci, chunk| {
        for (bi, d_img) in chunk.chunks_exact_mut(g.in_len()).enumerate() {
            let b = ci * per_task + bi;
            col2im_image(g, &d_cols[b * img_len..][..img_len], d_img);
        }
    });
}

fn col2im_image(g: &ConvGeom, d_cols: &[f32], d_img: &mut [f32]) {
    let (k, ci) = (g.k, g.c_in);
    let krow = k * k * ci;
    for ho in 0..g.h_out {
        for wo in 0..g.w_out {
            let row = &d_cols[(ho * g.w_out + wo) * krow..][..krow];
            let x0 = (wo * g.stride) as isize - g.pad_left as isize;
            let kx_lo = ((-x0).max(0) as usize).min(k);
            let kx_hi = ((g.w_in as isize - x0).max(0) as usize).min(k);
            if kx_lo >= kx_hi {
                continue;
            }
            for ky in 0..k {
                let y = (ho * g.stride + ky) as isize - g.pad_top as isize;
                if y < 0 || y >= g.h_in as isize {
                    continue;
                }
                let src = &row[ky * k * ci + kx_lo * ci..ky * k * ci + kx_hi * ci];
                // x0 + kx_lo >= 0 by construction of kx_lo.
                let px = (y as usize * g.w_in) as isize + x0 + kx_lo as isize;
                let dst0 = px as usize * ci;
                let dst = &mut d_img[dst0..dst0 + src.len()];
                for (d, &s) in dst.iter_mut().zip(src) {
                    *d += s;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batched conv layers
// ---------------------------------------------------------------------------

/// Forward conv over a whole batch as one im2col + GEMM (no activation):
/// `out[nb*ho*wo, co] = im2col(inp) @ W + b`.  `cols` is reusable
/// scratch, resized as needed.
#[allow(clippy::too_many_arguments)] // geometry + batch + buffers, all load-bearing
pub fn conv_forward_batch(
    pool: &NativePool,
    g: &ConvGeom,
    nb: usize,
    inp: &[f32],
    wgt: &[f32],
    bias: &[f32],
    cols: &mut Vec<f32>,
    out: &mut [f32],
) {
    let krow = im2col_row_len(g);
    let m = nb * g.h_out * g.w_out;
    debug_assert_eq!(out.len(), m * g.c_out);
    cols.resize(m * krow, 0.0);
    im2col(pool, g, nb, inp, cols);
    gemm_nn(pool, m, krow, g.c_out, cols, wgt, Some(bias), out, false);
}

/// Backward conv over a whole batch: `d_wgt += cols^T @ d_out`,
/// `d_bias += colsum(d_out)`, and (when `d_inp` is `Some`)
/// `d_inp = col2im(d_out @ W^T)` — three GEMMs against the packed
/// buffer.  `wgt_t` is the `(co, k*k*ci)` pre-transposed weight (only
/// needed when `d_inp` is requested); `cols`/`d_cols` are reusable
/// scratch.  `d_inp` is overwritten (not accumulated).
#[allow(clippy::too_many_arguments)]
pub fn conv_backward_batch(
    pool: &NativePool,
    g: &ConvGeom,
    nb: usize,
    inp: &[f32],
    wgt_t: Option<&[f32]>,
    d_out: &[f32],
    cols: &mut Vec<f32>,
    d_cols: &mut Vec<f32>,
    d_wgt: &mut [f32],
    d_bias: &mut [f32],
    d_inp: Option<&mut [f32]>,
) {
    let krow = im2col_row_len(g);
    let m = nb * g.h_out * g.w_out;
    debug_assert_eq!(d_out.len(), m * g.c_out);
    debug_assert_eq!(d_wgt.len(), krow * g.c_out);
    debug_assert_eq!(d_bias.len(), g.c_out);
    cols.resize(m * krow, 0.0);
    im2col(pool, g, nb, inp, cols);
    gemm_tn(pool, m, krow, g.c_out, cols, d_out, d_wgt);
    add_colsum(m, g.c_out, d_out, d_bias);
    if let Some(d_inp) = d_inp {
        let wgt_t = wgt_t.expect("conv_backward_batch: d_inp requires wgt_t");
        debug_assert_eq!(wgt_t.len(), krow * g.c_out);
        d_cols.resize(m * krow, 0.0);
        gemm_nn(pool, m, g.c_out, krow, d_out, wgt_t, None, d_cols, false);
        d_inp.iter_mut().for_each(|v| *v = 0.0);
        col2im_add(pool, g, nb, d_cols, d_inp);
    }
}

// ---------------------------------------------------------------------------
// Batched GRU
// ---------------------------------------------------------------------------

/// Saved forward state of one batched GRU step (all rows), mirroring
/// [`super::ops::GruTrace`] with flat `[nb, hidden]` storage.
#[derive(Default)]
pub struct GruBatchTrace {
    /// Effective (already done-masked) previous hidden state.
    pub h_prev: Vec<f32>,
    pub r: Vec<f32>,
    pub z: Vec<f32>,
    pub n: Vec<f32>,
    /// Pre-tanh hidden-side candidate gate `gh[2H..3H]`.
    pub gh_n: Vec<f32>,
}

impl GruBatchTrace {
    fn resize(&mut self, len: usize) {
        self.h_prev.resize(len, 0.0);
        self.r.resize(len, 0.0);
        self.z.resize(len, 0.0);
        self.n.resize(len, 0.0);
        self.gh_n.resize(len, 0.0);
    }
}

/// One GRU cell step for `nb` rows at once, PyTorch gate convention
/// (identical math to [`super::ops::gru_forward_row`], with the two gate
/// projections `gx = x @ wx + b[0]` and `gh = h @ wh + b[1]` run as
/// batch GEMMs).  `gx`/`gh` are reusable scratch.
#[allow(clippy::too_many_arguments)]
pub fn gru_forward_batch(
    pool: &NativePool,
    nb: usize,
    fdim: usize,
    hidden: usize,
    x: &[f32],
    h_prev: &[f32],
    wx: &[f32],
    wh: &[f32],
    b: &[f32],
    h_new: &mut [f32],
    gx: &mut Vec<f32>,
    gh: &mut Vec<f32>,
    mut trace: Option<&mut GruBatchTrace>,
) {
    let g3 = 3 * hidden;
    debug_assert_eq!(x.len(), nb * fdim);
    debug_assert_eq!(h_prev.len(), nb * hidden);
    debug_assert_eq!(h_new.len(), nb * hidden);
    debug_assert_eq!(wx.len(), fdim * g3);
    debug_assert_eq!(wh.len(), hidden * g3);
    debug_assert_eq!(b.len(), 2 * g3);
    gx.resize(nb * g3, 0.0);
    gh.resize(nb * g3, 0.0);
    gemm_nn(pool, nb, fdim, g3, x, wx, Some(&b[..g3]), gx, false);
    gemm_nn(pool, nb, hidden, g3, h_prev, wh, Some(&b[g3..]), gh, false);
    if let Some(t) = trace.as_deref_mut() {
        t.resize(nb * hidden);
        t.h_prev.copy_from_slice(h_prev);
        for i in 0..nb {
            t.gh_n[i * hidden..(i + 1) * hidden]
                .copy_from_slice(&gh[i * g3 + 2 * hidden..i * g3 + 3 * hidden]);
        }
    }
    for i in 0..nb {
        let gx_row = &gx[i * g3..][..g3];
        let gh_row = &gh[i * g3..][..g3];
        for j in 0..hidden {
            let r = sigmoid(gx_row[j] + gh_row[j]);
            let z = sigmoid(gx_row[hidden + j] + gh_row[hidden + j]);
            let n = (gx_row[2 * hidden + j] + r * gh_row[2 * hidden + j]).tanh();
            h_new[i * hidden + j] = (1.0 - z) * n + z * h_prev[i * hidden + j];
            if let Some(t) = trace.as_deref_mut() {
                let ij = i * hidden + j;
                t.r[ij] = r;
                t.z[ij] = z;
                t.n[ij] = n;
            }
        }
    }
}

/// Elementwise part of the batched GRU backward: from `d_h_new` and the
/// forward trace, produce the gate-preactivation gradients `dgx`/`dgh`
/// (each `[nb, 3H]`) and the direct carry `d_h_prev = d_h_new * z`.
/// The caller finishes with four GEMMs:
/// `d_wx += x^T dgx`, `d_wh += h_prev^T dgh`,
/// `d_x = dgx @ wx^T`, `d_h_prev += dgh @ wh^T` (plus bias colsums) —
/// exactly the decomposition of [`super::ops::gru_backward_row`].
pub fn gru_backward_gates(
    nb: usize,
    hidden: usize,
    trace: &GruBatchTrace,
    d_h_new: &[f32],
    dgx: &mut Vec<f32>,
    dgh: &mut Vec<f32>,
    d_h_prev: &mut [f32],
) {
    let g3 = 3 * hidden;
    debug_assert_eq!(d_h_new.len(), nb * hidden);
    debug_assert_eq!(d_h_prev.len(), nb * hidden);
    debug_assert_eq!(trace.r.len(), nb * hidden);
    dgx.resize(nb * g3, 0.0);
    dgh.resize(nb * g3, 0.0);
    for i in 0..nb {
        let dgx_row = &mut dgx[i * g3..][..g3];
        let dgh_row = &mut dgh[i * g3..][..g3];
        for j in 0..hidden {
            let ij = i * hidden + j;
            let (r, z, n) = (trace.r[ij], trace.z[ij], trace.n[ij]);
            let dh = d_h_new[ij];
            // h' = (1-z)*n + z*h_prev
            let dz_pre = dh * (trace.h_prev[ij] - n) * z * (1.0 - z);
            let dn_pre = dh * (1.0 - z) * (1.0 - n * n);
            let dr_pre = dn_pre * trace.gh_n[ij] * r * (1.0 - r);
            dgx_row[j] = dr_pre;
            dgx_row[hidden + j] = dz_pre;
            dgx_row[2 * hidden + j] = dn_pre;
            dgh_row[j] = dr_pre;
            dgh_row[hidden + j] = dz_pre;
            dgh_row[2 * hidden + j] = dn_pre * r;
            d_h_prev[ij] = dh * z;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::ops;
    use super::*;
    use crate::util::Rng;

    fn rand_vec(rng: &mut Rng, n: usize, s: f32) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(-s, s)).collect()
    }

    fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length mismatch");
        for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
            let scale = 1.0f32.max(x.abs()).max(y.abs());
            assert!(
                (x - y).abs() <= tol * scale,
                "{what}[{i}]: {x} vs {y} (tol {tol})"
            );
        }
    }

    #[test]
    fn gemm_nn_matches_naive_triple_loop() {
        let mut rng = Rng::new(1);
        let pool = NativePool::new(3);
        for &(m, k, n) in &[(1usize, 1usize, 1usize), (5, 7, 3), (13, 300, 17), (33, 64, 20)] {
            let a = rand_vec(&mut rng, m * k, 1.0);
            let b = rand_vec(&mut rng, k * n, 1.0);
            let bias = rand_vec(&mut rng, n, 0.5);
            let mut c = vec![0.0f32; m * n];
            gemm_nn(&pool, m, k, n, &a, &b, Some(&bias), &mut c, false);
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for j in 0..n {
                    let mut acc = bias[j];
                    for kk in 0..k {
                        acc += a[i * k + kk] * b[kk * n + j];
                    }
                    want[i * n + j] = acc;
                }
            }
            assert_close(&c, &want, 1e-4, "gemm_nn");
            // Accumulate doubles the product part.
            let mut c2 = c.clone();
            gemm_nn(&pool, m, k, n, &a, &b, None, &mut c2, true);
            for i in 0..m * n {
                let prod = c[i] - bias[i % n];
                assert!((c2[i] - (c[i] + prod)).abs() <= 1e-3, "accumulate at {i}");
            }
        }
    }

    #[test]
    fn gemm_tn_matches_naive() {
        let mut rng = Rng::new(2);
        let pool = NativePool::new(2);
        let (m, k, n) = (40usize, 23usize, 9usize);
        let a = rand_vec(&mut rng, m * k, 1.0);
        let b = rand_vec(&mut rng, m * n, 1.0);
        let mut c = vec![0.0f32; k * n];
        gemm_tn(&pool, m, k, n, &a, &b, &mut c);
        let mut want = vec![0.0f32; k * n];
        for kk in 0..k {
            for j in 0..n {
                let mut acc = 0.0f32;
                for i in 0..m {
                    acc += a[i * k + kk] * b[i * n + j];
                }
                want[kk * n + j] = acc;
            }
        }
        assert_close(&c, &want, 1e-4, "gemm_tn");
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(3);
        let (r, c) = (11usize, 7usize);
        let src = rand_vec(&mut rng, r * c, 1.0);
        let mut t = vec![0.0f32; r * c];
        let mut back = vec![0.0f32; r * c];
        transpose(&src, r, c, &mut t);
        transpose(&t, c, r, &mut back);
        assert_eq!(src, back);
        assert_eq!(t[3 * r + 2], src[2 * c + 3]);
    }

    #[test]
    fn conv_batch_matches_scalar_reference() {
        // Asymmetric SAME padding geometry (odd input, stride 2).
        let g = ConvGeom::same(9, 12, 3, 5, 4, 2);
        let nb = 3;
        let mut rng = Rng::new(4);
        let pool = NativePool::new(2);
        let inp = rand_vec(&mut rng, nb * g.in_len(), 0.5);
        let wgt = rand_vec(&mut rng, g.w_len(), 0.5);
        let bias = rand_vec(&mut rng, g.c_out, 0.2);
        let mut cols = Vec::new();
        let mut out = vec![0.0f32; nb * g.out_len()];
        conv_forward_batch(&pool, &g, nb, &inp, &wgt, &bias, &mut cols, &mut out);
        let mut want_row = vec![0.0f32; g.out_len()];
        for b in 0..nb {
            ops::conv_forward(&g, &inp[b * g.in_len()..][..g.in_len()], &wgt, &bias, &mut want_row);
            assert_close(
                &out[b * g.out_len()..][..g.out_len()],
                &want_row,
                1e-5,
                "conv_forward_batch",
            );
        }

        // Backward: dW / db / dX against the scalar reference.
        let d_out = rand_vec(&mut rng, nb * g.out_len(), 0.5);
        let mut wgt_t = vec![0.0f32; g.w_len()];
        transpose(&wgt, im2col_row_len(&g), g.c_out, &mut wgt_t);
        let mut d_cols = Vec::new();
        let mut d_wgt = vec![0.0f32; g.w_len()];
        let mut d_bias = vec![0.0f32; g.c_out];
        let mut d_inp = vec![0.0f32; nb * g.in_len()];
        conv_backward_batch(
            &pool, &g, nb, &inp, Some(&wgt_t), &d_out, &mut cols, &mut d_cols,
            &mut d_wgt, &mut d_bias, Some(&mut d_inp),
        );
        let mut w_dw = vec![0.0f32; g.w_len()];
        let mut w_db = vec![0.0f32; g.c_out];
        let mut w_di = vec![0.0f32; nb * g.in_len()];
        for b in 0..nb {
            ops::conv_backward(
                &g,
                &inp[b * g.in_len()..][..g.in_len()],
                &wgt,
                &d_out[b * g.out_len()..][..g.out_len()],
                &mut w_dw,
                &mut w_db,
                Some(&mut w_di[b * g.in_len()..(b + 1) * g.in_len()]),
            );
        }
        assert_close(&d_wgt, &w_dw, 1e-5, "conv d_wgt");
        assert_close(&d_bias, &w_db, 1e-5, "conv d_bias");
        assert_close(&d_inp, &w_di, 1e-5, "conv d_inp");
    }

    #[test]
    fn gru_batch_matches_row_reference() {
        let (nb, f, h) = (5usize, 6usize, 4usize);
        let mut rng = Rng::new(5);
        let pool = NativePool::new(2);
        let x = rand_vec(&mut rng, nb * f, 1.0);
        let hp = rand_vec(&mut rng, nb * h, 1.0);
        let wx = rand_vec(&mut rng, f * 3 * h, 0.7);
        let wh = rand_vec(&mut rng, h * 3 * h, 0.7);
        let b = rand_vec(&mut rng, 6 * h, 0.3);
        let mut h_new = vec![0.0f32; nb * h];
        let (mut gx, mut gh) = (Vec::new(), Vec::new());
        let mut trace = GruBatchTrace::default();
        gru_forward_batch(
            &pool, nb, f, h, &x, &hp, &wx, &wh, &b, &mut h_new, &mut gx, &mut gh,
            Some(&mut trace),
        );
        let mut scratch = vec![0.0f32; 6 * h];
        let mut want = vec![0.0f32; h];
        for i in 0..nb {
            ops::gru_forward_row(
                &x[i * f..][..f], &hp[i * h..][..h], &wx, &wh, &b, &mut want,
                &mut scratch, None,
            );
            assert_close(&h_new[i * h..][..h], &want, 1e-5, "gru_forward_batch");
        }
        // Gate gradients against the row reference's full backward.
        let d_h = rand_vec(&mut rng, nb * h, 1.0);
        let (mut dgx, mut dgh) = (Vec::new(), Vec::new());
        let mut d_hp = vec![0.0f32; nb * h];
        gru_backward_gates(nb, h, &trace, &d_h, &mut dgx, &mut dgh, &mut d_hp);
        // Finish the backward with the GEMM decomposition.
        let mut d_wx = vec![0.0f32; wx.len()];
        let mut d_wh = vec![0.0f32; wh.len()];
        let mut d_b = vec![0.0f32; b.len()];
        let mut d_x = vec![0.0f32; nb * f];
        gemm_tn(&pool, nb, f, 3 * h, &x, &dgx, &mut d_wx);
        gemm_tn(&pool, nb, h, 3 * h, &trace.h_prev, &dgh, &mut d_wh);
        let (db_x, db_h) = d_b.split_at_mut(3 * h);
        add_colsum(nb, 3 * h, &dgx, db_x);
        add_colsum(nb, 3 * h, &dgh, db_h);
        let mut wx_t = vec![0.0f32; wx.len()];
        let mut wh_t = vec![0.0f32; wh.len()];
        transpose(&wx, f, 3 * h, &mut wx_t);
        transpose(&wh, h, 3 * h, &mut wh_t);
        gemm_nn(&pool, nb, 3 * h, f, &dgx, &wx_t, None, &mut d_x, false);
        gemm_nn(&pool, nb, 3 * h, h, &dgh, &wh_t, None, &mut d_hp, true);

        // Reference: row-by-row scalar backward.
        let mut r_dwx = vec![0.0f32; wx.len()];
        let mut r_dwh = vec![0.0f32; wh.len()];
        let mut r_db = vec![0.0f32; b.len()];
        let mut r_dx = vec![0.0f32; nb * f];
        let mut r_dhp = vec![0.0f32; nb * h];
        for i in 0..nb {
            let mut row_trace = ops::GruTrace::new(h);
            let mut h_out = vec![0.0f32; h];
            ops::gru_forward_row(
                &x[i * f..][..f], &hp[i * h..][..h], &wx, &wh, &b, &mut h_out,
                &mut scratch, Some(&mut row_trace),
            );
            ops::gru_backward_row(
                &x[i * f..][..f],
                &row_trace,
                &wx,
                &wh,
                &d_h[i * h..][..h],
                &mut r_dx[i * f..(i + 1) * f],
                &mut r_dhp[i * h..(i + 1) * h],
                &mut r_dwx,
                &mut r_dwh,
                &mut r_db,
                &mut scratch,
            );
        }
        assert_close(&d_wx, &r_dwx, 1e-5, "gru d_wx");
        assert_close(&d_wh, &r_dwh, 1e-5, "gru d_wh");
        assert_close(&d_b, &r_db, 1e-5, "gru d_b");
        assert_close(&d_x, &r_dx, 1e-5, "gru d_x");
        assert_close(&d_hp, &r_dhp, 1e-5, "gru d_h_prev");
    }
}
