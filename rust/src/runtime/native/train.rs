//! The native `train` program: one fused APPO SGD step, mirroring
//! `python/compile/model.py::train_step` —
//!
//! 1. forward over the (B, T) trajectory batch with BPTT through the GRU,
//! 2. V-trace off-policy correction (`kernels/ref.py::vtrace_ref`, rho_bar =
//!    c_bar = 1 as in Table A.5) with stop-gradient targets,
//! 3. PPO-clipped policy gradient on normalised V-trace advantages +
//!    value regression + entropy bonus,
//! 4. analytic backprop (heads -> GRU BPTT -> fc/conv encoder),
//! 5. global-norm gradient clipping and an in-step bias-corrected Adam
//!    update.
//!
//! Inputs:  params[n] | m[n] | v[n] | step | hypers | obs(B,T,H,W,C) u8 |
//!          last_obs(B,H,W,C) u8 | h0(B,hid) | actions(B,T,heads) i32 |
//!          behavior_lp(B,T) | rewards(B,T) | dones(B,T)
//! Outputs: params'[n] | m'[n] | v'[n] | step' | metrics[8]
//!
//! Compute engine (batch-native): the encoder runs as im2col+GEMM over
//! fixed-size frame chunks ([`ENC_CHUNK`] frames — activation
//! checkpointing, so the backward pass recomputes each chunk's
//! activations and the im2col working set stays O(chunk), not O(B*T));
//! conv dW/dX are GEMMs against the same packed buffer.  The GRU unroll
//! and BPTT run two gate GEMMs per timestep over all B rows; the heads +
//! value output layer is a single packed GEMM over all B*T cores, as is
//! its backward.  Weight transposes (for the `dX = dY @ W^T` GEMMs) are
//! computed once per call; all scratch is reused across calls via
//! [`TrainProgram::scratch`].  Gradient accumulation order matches the
//! old per-row path (ascending sample index), so metrics and descent
//! behaviour are unchanged.
//!
//! The gradient of the bootstrap branch (`last_obs` encoder + final GRU
//! step) is exactly zero because `v_boot` is stop-gradient in the loss, so
//! that branch is forward-only here too.

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::gemm::{self, GruBatchTrace};
use super::pool::NativePool;
use super::{
    backward_batch, encode_batch, pack_heads_value, EncBwdScratch, EncScratch,
    Grads, ModelDef, ParamView, WeightsT, HYP_B1, HYP_B2, HYP_CLIP, HYP_ENT,
    HYP_EPS, HYP_GAMMA, HYP_LR, HYP_MAX_GN, HYP_VF,
};
use crate::runtime::{Literal, Program};

/// Frames per encoder chunk (forward and recomputed backward).  A fixed
/// constant — never derived from the thread count — so results are
/// bit-identical for any `SF_NATIVE_THREADS`.
const ENC_CHUNK: usize = 64;

pub(crate) struct TrainProgram {
    pub def: Arc<ModelDef>,
    scratch: Mutex<Vec<TrainScratch>>,
}

impl TrainProgram {
    pub fn new(def: Arc<ModelDef>) -> TrainProgram {
        TrainProgram { def, scratch: Mutex::new(Vec::new()) }
    }
}

impl Program for TrainProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();
        let out = run_train(&self.def, inputs, &mut s);
        self.scratch.lock().unwrap().push(s);
        out
    }
}

/// Reusable buffers for one train-step invocation (see module docs).
#[derive(Default)]
struct TrainScratch {
    enc: EncScratch,
    bwd: EncBwdScratch,
    /// `[t*bsz + b, fc]` — time-major, so each timestep's GRU input is
    /// one contiguous GEMM operand.
    emb: Vec<f32>,
    emb_last: Vec<f32>,
    h_seq: Vec<f32>,
    h_masked: Vec<f32>,
    h_boot: Vec<f32>,
    gx: Vec<f32>,
    gh: Vec<f32>,
    traces: Vec<GruBatchTrace>,
    w_all: Vec<f32>,
    b_all: Vec<f32>,
    w_all_t: Vec<f32>,
    out_all: Vec<f32>,
    d_out_all: Vec<f32>,
    d_w_all: Vec<f32>,
    d_b_all: Vec<f32>,
    d_cores: Vec<f32>,
    dgx: Vec<f32>,
    dgh: Vec<f32>,
    dh_t: Vec<f32>,
    d_h_prev: Vec<f32>,
    dh_carry: Vec<f32>,
    d_emb: Vec<f32>,
    d_emb_chunk: Vec<f32>,
    wx_t: Vec<f32>,
    wh_t: Vec<f32>,
}

/// Split three consecutive GRU parameter-grad buffers out of `grads`.
fn gru_grads<'a>(
    grads: &'a mut Grads,
    def: &ModelDef,
) -> (&'a mut [f32], &'a mut [f32], &'a mut [f32]) {
    let wx = def.idx_gru_wx();
    let (lo, rest) = grads.0.split_at_mut(wx + 1);
    let (mid, hi) = rest.split_at_mut(1);
    (&mut lo[wx], &mut mid[0], &mut hi[0])
}

#[allow(clippy::needless_range_loop)]
fn run_train(def: &ModelDef, inputs: &[&Literal], s: &mut TrainScratch) -> Result<Vec<Literal>> {
    let n = def.n_params();
    if inputs.len() != 3 * n + 9 {
        return Err(anyhow!(
            "train takes {} inputs (3x{} params/m/v + step + hypers + 7 batch \
             tensors), got {}",
            3 * n + 9,
            n,
            inputs.len()
        ));
    }
    let pv = ParamView::parse(def, &inputs[..n])?;
    let m_in: Vec<&[f32]> = collect_f32(&inputs[n..2 * n])?;
    let v_in: Vec<&[f32]> = collect_f32(&inputs[2 * n..3 * n])?;
    let step_in = inputs[3 * n].as_f32()?[0];
    let hypers = inputs[3 * n + 1].as_f32()?;
    if hypers.len() <= HYP_EPS {
        return Err(anyhow!("train: hyper vector has {} entries", hypers.len()));
    }
    let obs = inputs[3 * n + 2].as_u8()?;
    let last_obs = inputs[3 * n + 3].as_u8()?;
    let h0 = inputs[3 * n + 4].as_f32()?;
    let actions = inputs[3 * n + 5].as_i32()?;
    let blp = inputs[3 * n + 6].as_f32()?;
    let rewards = inputs[3 * n + 7].as_f32()?;
    let dones = inputs[3 * n + 8].as_f32()?;

    let obs_dims = inputs[3 * n + 2].dims();
    if obs_dims.len() != 5 {
        return Err(anyhow!("train obs must be (B,T,H,W,C), got {obs_dims:?}"));
    }
    let (bsz, t_len) = (obs_dims[0], obs_dims[1]);
    let obs_len = def.obs_len();
    if [obs_dims[2], obs_dims[3], obs_dims[4]] != def.obs
        || obs.len() != bsz * t_len * obs_len
    {
        return Err(anyhow!("train obs geometry {obs_dims:?} != spec {:?}", def.obs));
    }
    let hid = def.hidden;
    let n_heads = def.n_heads();
    let ta = def.total_actions();
    let nbt = bsz * t_len;
    if last_obs.len() != bsz * obs_len
        || h0.len() != bsz * hid
        || actions.len() != nbt * n_heads
        || blp.len() != nbt
        || rewards.len() != nbt
        || dones.len() != nbt
    {
        return Err(anyhow!("train batch tensor sizes inconsistent with obs (B={bsz}, T={t_len})"));
    }

    let (gamma, clip) = (hypers[HYP_GAMMA], hypers[HYP_CLIP]);
    let (ent_coef, vf_coef) = (hypers[HYP_ENT], hypers[HYP_VF]);
    let inv_n = 1.0f32 / nbt as f32;
    let pool = NativePool::global();

    // ---- 1. encode every frame (chunked im2col+GEMM, scattered into the
    //         time-major embedding buffer) ---------------------------------
    let fc = def.fc_dim;
    s.emb.resize(nbt * fc, 0.0);
    let mut f0 = 0usize;
    while f0 < nbt {
        let nb = ENC_CHUNK.min(nbt - f0);
        encode_batch(def, &pv, pool, &obs[f0 * obs_len..(f0 + nb) * obs_len], nb, &mut s.enc);
        for j in 0..nb {
            let fi = f0 + j;
            let (b, t) = (fi / t_len, fi % t_len);
            s.emb[(t * bsz + b) * fc..(t * bsz + b + 1) * fc]
                .copy_from_slice(&s.enc.emb[j * fc..(j + 1) * fc]);
        }
        f0 += nb;
    }
    encode_batch(def, &pv, pool, last_obs, bsz, &mut s.enc);
    s.emb_last.resize(bsz * fc, 0.0);
    s.emb_last.copy_from_slice(&s.enc.emb[..bsz * fc]);

    // ---- 2. GRU unroll, one batched step per timestep ---------------------
    // done *before* step t resets the hidden state (dones shifted right).
    s.h_seq.resize(t_len * bsz * hid, 0.0);
    s.h_masked.resize(bsz * hid, 0.0);
    if s.traces.len() < t_len {
        s.traces.resize_with(t_len, GruBatchTrace::default);
    }
    for t in 0..t_len {
        for b in 0..bsz {
            let mask = if t == 0 { 1.0 } else { 1.0 - dones[b * t_len + t - 1] };
            let h_prev: &[f32] = if t == 0 {
                &h0[b * hid..(b + 1) * hid]
            } else {
                &s.h_seq[((t - 1) * bsz + b) * hid..((t - 1) * bsz + b + 1) * hid]
            };
            for (hm, &hp) in s.h_masked[b * hid..(b + 1) * hid].iter_mut().zip(h_prev) {
                *hm = hp * mask;
            }
        }
        let x_t = &s.emb[t * bsz * fc..(t + 1) * bsz * fc];
        let h_new = &mut s.h_seq[t * bsz * hid..(t + 1) * bsz * hid];
        gemm::gru_forward_batch(
            pool, bsz, fc, hid, x_t, &s.h_masked, pv.gru_wx, pv.gru_wh, pv.gru_b,
            h_new, &mut s.gx, &mut s.gh, Some(&mut s.traces[t]),
        );
    }

    // Bootstrap value for x_{T+1} (stop-gradient: forward only).
    let mut v_boot = vec![0.0f32; bsz];
    {
        for b in 0..bsz {
            let mask = 1.0 - dones[b * t_len + t_len - 1];
            let h_last =
                &s.h_seq[((t_len - 1) * bsz + b) * hid..((t_len - 1) * bsz + b + 1) * hid];
            for (hm, &hp) in s.h_masked[b * hid..(b + 1) * hid].iter_mut().zip(h_last) {
                *hm = hp * mask;
            }
        }
        s.h_boot.resize(bsz * hid, 0.0);
        gemm::gru_forward_batch(
            pool, bsz, fc, hid, &s.emb_last, &s.h_masked, pv.gru_wx, pv.gru_wh,
            pv.gru_b, &mut s.h_boot, &mut s.gx, &mut s.gh, None,
        );
        gemm::gemm_nn(pool, bsz, hid, 1, &s.h_boot, pv.value_w, Some(pv.value_b), &mut v_boot, false);
    }

    // ---- 3. heads + value over all cores: one packed GEMM -----------------
    let m_all = t_len * bsz;
    let ta1 = ta + 1;
    pack_heads_value(def, &pv, &mut s.w_all, &mut s.b_all);
    s.out_all.resize(m_all * ta1, 0.0);
    gemm::gemm_nn(pool, m_all, hid, ta1, &s.h_seq, &s.w_all, Some(&s.b_all), &mut s.out_all, false);
    let mut values = vec![0.0f32; m_all];
    for i in 0..m_all {
        values[i] = s.out_all[i * ta1 + ta];
    }

    // ---- 4. log-probs, entropy, importance ratios -------------------------
    // target_lp/entropy per (t, b); actions tensor is batch-major.
    let mut target_lp = vec![0.0f32; t_len * bsz];
    let mut entropy = vec![0.0f32; t_len * bsz];
    let max_head = *def.heads.iter().max().unwrap_or(&1);
    let mut lsm = vec![0.0f32; max_head];
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let row = &s.out_all[i * ta1..i * ta1 + ta];
            let a_row = &actions[(b * t_len + t) * n_heads..(b * t_len + t + 1) * n_heads];
            let (mut lp, mut ent) = (0.0f32, 0.0f32);
            let mut off = 0usize;
            for (hd, &hn) in def.heads.iter().enumerate() {
                crate::util::log_softmax(&row[off..off + hn], &mut lsm[..hn]);
                let a = a_row[hd];
                if a < 0 || a as usize >= hn {
                    return Err(anyhow!("train: action {a} out of range for head {hd} ({hn})"));
                }
                lp += lsm[a as usize];
                for &l in &lsm[..hn] {
                    ent -= l.exp() * l;
                }
                off += hn;
            }
            target_lp[i] = lp;
            entropy[i] = ent;
        }
    }

    // ---- 5. V-trace (rho_bar = c_bar = 1, Table A.5) ----------------------
    let mut rho_c = vec![0.0f32; t_len * bsz];
    let mut vs = vec![0.0f32; t_len * bsz];
    let mut adv = vec![0.0f32; t_len * bsz];
    for b in 0..bsz {
        let mut acc = 0.0f32;
        for t in (0..t_len).rev() {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let rho = (target_lp[i] - blp[bt]).exp();
            let rc = rho.min(1.0);
            let cc = rho.min(1.0);
            rho_c[i] = rc;
            let disc = gamma * (1.0 - dones[bt]);
            let v_tp1 = if t + 1 == t_len { v_boot[b] } else { values[(t + 1) * bsz + b] };
            let delta = rc * (rewards[bt] + disc * v_tp1 - values[i]);
            acc = delta + disc * cc * acc;
            vs[i] = values[i] + acc;
        }
        for t in 0..t_len {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let disc = gamma * (1.0 - dones[bt]);
            let vs_tp1 = if t + 1 == t_len { v_boot[b] } else { vs[(t + 1) * bsz + b] };
            adv[i] = rho_c[i] * (rewards[bt] + disc * vs_tp1 - values[i]);
        }
    }

    // Advantage normalisation (standard APPO practice).
    let adv_mean = (adv.iter().map(|&x| x as f64).sum::<f64>() / nbt as f64) as f32;
    let adv_var = (adv
        .iter()
        .map(|&x| {
            let d = (x - adv_mean) as f64;
            d * d
        })
        .sum::<f64>()
        / nbt as f64) as f32;
    let adv_std = adv_var.sqrt();
    for a in adv.iter_mut() {
        *a = (*a - adv_mean) / (adv_std + 1e-5);
    }

    // ---- 6. losses + metrics ----------------------------------------------
    let (lo, hi) = (1.0 / (1.0 + clip), 1.0 + clip);
    let mut pg_loss = 0.0f64;
    let mut v_loss = 0.0f64;
    let mut ent_mean = 0.0f64;
    let mut approx_kl = 0.0f64;
    let mut mean_rho = 0.0f64;
    let mut mean_vs = 0.0f64;
    // d(total)/d(target_lp) and d(total)/d(values), filled in the same pass.
    let mut d_lp = vec![0.0f32; t_len * bsz];
    let mut d_values = vec![0.0f32; t_len * bsz];
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let ratio = (target_lp[i] - blp[bt]).exp();
            let t1 = ratio * adv[i];
            let t2 = ratio.clamp(lo, hi) * adv[i];
            let surr = t1.min(t2);
            pg_loss -= surr as f64;
            // d surr/d lp: the unclipped branch contributes ratio*adv (== t1);
            // a selected clipped branch is constant in lp.
            let d_surr = if t1 <= t2 { t1 } else { 0.0 };
            d_lp[i] = -inv_n * d_surr;
            let verr = values[i] - vs[i];
            v_loss += 0.5 * (verr * verr) as f64;
            d_values[i] = vf_coef * inv_n * verr;
            ent_mean += entropy[i] as f64;
            approx_kl += (blp[bt] - target_lp[i]) as f64;
            mean_rho += rho_c[i] as f64;
            mean_vs += vs[i] as f64;
        }
    }
    pg_loss /= nbt as f64;
    v_loss /= nbt as f64;
    ent_mean /= nbt as f64;
    approx_kl /= nbt as f64;
    mean_rho /= nbt as f64;
    mean_vs /= nbt as f64;
    let total = pg_loss + vf_coef as f64 * v_loss - ent_coef as f64 * ent_mean;

    // ---- 7. backprop into logits/values, then the packed output layer -----
    let mut grads = Grads::new(def);
    s.d_out_all.resize(m_all * ta1, 0.0);
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let row = &s.out_all[i * ta1..i * ta1 + ta];
            let d_row = &mut s.d_out_all[i * ta1..(i + 1) * ta1];
            let a_row = &actions[(b * t_len + t) * n_heads..(b * t_len + t + 1) * n_heads];
            let mut off = 0usize;
            for (hd, &hn) in def.heads.iter().enumerate() {
                crate::util::log_softmax(&row[off..off + hn], &mut lsm[..hn]);
                let a = a_row[hd] as usize;
                // Head entropy (needed for dH/dl).
                let mut h_head = 0.0f32;
                for &l in &lsm[..hn] {
                    h_head -= l.exp() * l;
                }
                for j in 0..hn {
                    let p = lsm[j].exp();
                    let ind = if j == a { 1.0 } else { 0.0 };
                    // d total/d l_j = d_lp * (1{j=a} - p_j)
                    //               + ent_coef/N * p_j * (log p_j + H_head)
                    d_row[off + j] = d_lp[i] * (ind - p)
                        + ent_coef * inv_n * p * (lsm[j] + h_head);
                }
                off += hn;
            }
            d_row[ta] = d_values[i];
        }
    }
    // Packed parameter gradients, then unpack into the per-head buffers.
    s.d_w_all.resize(hid * ta1, 0.0);
    s.d_w_all.iter_mut().for_each(|v| *v = 0.0);
    s.d_b_all.resize(ta1, 0.0);
    s.d_b_all.iter_mut().for_each(|v| *v = 0.0);
    gemm::gemm_tn(pool, m_all, hid, ta1, &s.h_seq, &s.d_out_all, &mut s.d_w_all);
    gemm::add_colsum(m_all, ta1, &s.d_out_all, &mut s.d_b_all);
    {
        let mut off = 0usize;
        for (hd, &hn) in def.heads.iter().enumerate() {
            let (d_w, d_b) = grads.pair_mut(def.idx_head_w(hd), def.idx_head_b(hd));
            for r in 0..hid {
                for j in 0..hn {
                    d_w[r * hn + j] += s.d_w_all[r * ta1 + off + j];
                }
            }
            for j in 0..hn {
                d_b[j] += s.d_b_all[off + j];
            }
            off += hn;
        }
        let (d_vw, d_vb) = grads.pair_mut(def.idx_value_w(), def.idx_value_b());
        for r in 0..hid {
            d_vw[r] += s.d_w_all[r * ta1 + ta];
        }
        d_vb[0] += s.d_b_all[ta];
    }
    // d_cores = d_out_all @ W_all^T (one GEMM over all cores).
    s.w_all_t.resize(ta1 * hid, 0.0);
    gemm::transpose(&s.w_all, hid, ta1, &mut s.w_all_t);
    s.d_cores.resize(m_all * hid, 0.0);
    gemm::gemm_nn(pool, m_all, ta1, hid, &s.d_out_all, &s.w_all_t, None, &mut s.d_cores, false);

    // ---- 8. BPTT through the GRU, one batched step per timestep -----------
    s.wx_t.resize(fc * 3 * hid, 0.0);
    gemm::transpose(pv.gru_wx, fc, 3 * hid, &mut s.wx_t);
    s.wh_t.resize(hid * 3 * hid, 0.0);
    gemm::transpose(pv.gru_wh, hid, 3 * hid, &mut s.wh_t);
    s.d_emb.resize(nbt * fc, 0.0);
    s.dh_carry.resize(bsz * hid, 0.0);
    s.dh_carry.iter_mut().for_each(|v| *v = 0.0);
    s.dh_t.resize(bsz * hid, 0.0);
    s.d_h_prev.resize(bsz * hid, 0.0);
    for t in (0..t_len).rev() {
        for (idx, dt) in s.dh_t.iter_mut().enumerate() {
            *dt = s.dh_carry[idx] + s.d_cores[t * bsz * hid + idx];
        }
        gemm::gru_backward_gates(
            bsz, hid, &s.traces[t], &s.dh_t, &mut s.dgx, &mut s.dgh, &mut s.d_h_prev,
        );
        let x_t = &s.emb[t * bsz * fc..(t + 1) * bsz * fc];
        {
            let (d_wx, d_wh, d_b) = gru_grads(&mut grads, def);
            gemm::gemm_tn(pool, bsz, fc, 3 * hid, x_t, &s.dgx, d_wx);
            gemm::gemm_tn(pool, bsz, hid, 3 * hid, &s.traces[t].h_prev, &s.dgh, d_wh);
            let (db_x, db_h) = d_b.split_at_mut(3 * hid);
            gemm::add_colsum(bsz, 3 * hid, &s.dgx, db_x);
            gemm::add_colsum(bsz, 3 * hid, &s.dgh, db_h);
        }
        let d_emb_t = &mut s.d_emb[t * bsz * fc..(t + 1) * bsz * fc];
        gemm::gemm_nn(pool, bsz, 3 * hid, fc, &s.dgx, &s.wx_t, None, d_emb_t, false);
        gemm::gemm_nn(pool, bsz, 3 * hid, hid, &s.dgh, &s.wh_t, None, &mut s.d_h_prev, true);
        // Through the done-reset mask into the *raw* h_{t-1}.
        for b in 0..bsz {
            let mask = if t == 0 { 1.0 } else { 1.0 - dones[b * t_len + t - 1] };
            for k in 0..hid {
                s.dh_carry[b * hid + k] = s.d_h_prev[b * hid + k] * mask;
            }
        }
    }
    // dh_carry now holds d/d h0 — unused (h0 is an input, not a parameter).

    // ---- 9. encoder backward, chunked (recomputed activations) ------------
    let wt = WeightsT::build(def, &pv);
    let mut f0 = 0usize;
    while f0 < nbt {
        let nb = ENC_CHUNK.min(nbt - f0);
        s.d_emb_chunk.resize(nb * fc, 0.0);
        for j in 0..nb {
            let fi = f0 + j;
            let (b, t) = (fi / t_len, fi % t_len);
            s.d_emb_chunk[j * fc..(j + 1) * fc]
                .copy_from_slice(&s.d_emb[(t * bsz + b) * fc..(t * bsz + b + 1) * fc]);
        }
        encode_batch(def, &pv, pool, &obs[f0 * obs_len..(f0 + nb) * obs_len], nb, &mut s.enc);
        backward_batch(def, &pv, &wt, pool, nb, &mut s.enc, &mut s.d_emb_chunk, &mut grads, &mut s.bwd);
        f0 += nb;
    }

    // ---- 10. global-norm clip + Adam --------------------------------------
    let gnorm = grads.global_norm();
    let max_gn = hypers[HYP_MAX_GN];
    if gnorm > max_gn {
        grads.scale(max_gn / gnorm);
    }

    let (b1, b2) = (hypers[HYP_B1], hypers[HYP_B2]);
    let (eps, lr) = (hypers[HYP_EPS], hypers[HYP_LR]);
    let new_step = step_in + 1.0;
    let bc1 = 1.0 - b1.powf(new_step);
    let bc2 = 1.0 - b2.powf(new_step);
    let defs = def.param_defs();
    let mut out: Vec<Literal> = Vec::with_capacity(3 * n + 2);
    let mut new_m_all: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut new_v_all: Vec<Vec<f32>> = Vec::with_capacity(n);
    for (pi, (_, shape)) in defs.iter().enumerate() {
        let p = pv_flat(&pv, def, pi);
        let g = &grads.0[pi];
        let (m0, v0) = (m_in[pi], v_in[pi]);
        if m0.len() != p.len() || v0.len() != p.len() {
            return Err(anyhow!("train: optimizer state shape mismatch at param {pi}"));
        }
        let mut p_new = vec![0.0f32; p.len()];
        let mut m_new = vec![0.0f32; p.len()];
        let mut v_new = vec![0.0f32; p.len()];
        for j in 0..p.len() {
            let m2 = b1 * m0[j] + (1.0 - b1) * g[j];
            let v2 = b2 * v0[j] + (1.0 - b2) * g[j] * g[j];
            let upd = lr * (m2 / bc1) / ((v2 / bc2).sqrt() + eps);
            p_new[j] = p[j] - upd;
            m_new[j] = m2;
            v_new[j] = v2;
        }
        out.push(Literal::f32(shape, p_new)?);
        new_m_all.push(m_new);
        new_v_all.push(v_new);
    }
    for (pi, data) in new_m_all.into_iter().enumerate() {
        out.push(Literal::f32(&defs[pi].1, data)?);
    }
    for (pi, data) in new_v_all.into_iter().enumerate() {
        out.push(Literal::f32(&defs[pi].1, data)?);
    }
    out.push(Literal::f32(&[], vec![new_step])?);
    let metrics = vec![
        total as f32,
        pg_loss as f32,
        v_loss as f32,
        ent_mean as f32,
        approx_kl as f32,
        gnorm,
        mean_rho as f32,
        mean_vs as f32,
    ];
    out.push(Literal::f32(&[8], metrics)?);
    Ok(out)
}

/// Flat slice of parameter `pi` from the view (defs order).
fn pv_flat<'a>(pv: &ParamView<'a>, def: &ModelDef, pi: usize) -> &'a [f32] {
    let nc = def.conv.len();
    if pi < 2 * nc {
        let layer = pi / 2;
        if pi % 2 == 0 {
            pv.conv_w[layer]
        } else {
            pv.conv_b[layer]
        }
    } else if pi == def.idx_fc_w() {
        pv.fc_w
    } else if pi == def.idx_fc_b() {
        pv.fc_b
    } else if pi == def.idx_gru_wx() {
        pv.gru_wx
    } else if pi == def.idx_gru_wh() {
        pv.gru_wh
    } else if pi == def.idx_gru_b() {
        pv.gru_b
    } else if pi == def.idx_value_w() {
        pv.value_w
    } else if pi == def.idx_value_b() {
        pv.value_b
    } else {
        let rel = pi - (def.idx_fc_w() + 5);
        let head = rel / 2;
        if rel % 2 == 0 {
            pv.head_w[head]
        } else {
            pv.head_b[head]
        }
    }
}

fn collect_f32<'a>(lits: &[&'a Literal]) -> Result<Vec<&'a [f32]>> {
    lits.iter().map(|l| l.as_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_i32, lit_u32_scalar, lit_u8};

    fn run_once(def: &ModelDef, lits: &[Literal]) -> Vec<Literal> {
        let refs: Vec<&Literal> = lits.iter().collect();
        let mut s = TrainScratch::default();
        run_train(def, &refs, &mut s).unwrap()
    }

    /// Build a full input set for the tiny spec with a reproducible batch.
    fn tiny_inputs(lr: f32) -> (Arc<ModelDef>, Vec<Literal>) {
        let def = Arc::new(ModelDef::builtin("tiny").unwrap());
        let init = super::super::InitProgram { def: def.clone() };
        let seed = lit_u32_scalar(11);
        let params = init.run(&[&seed]).unwrap();
        let n = def.n_params();
        let (b, t) = (def.train_batch, def.rollout);
        let mut rng = crate::util::Rng::new(77);
        let mut lits: Vec<Literal> = Vec::new();
        lits.extend(params.iter().cloned());
        for (_, shape) in def.param_defs() {
            let len: usize = shape.iter().product::<usize>().max(1);
            lits.push(lit_f32(&shape, &vec![0.0; len]).unwrap());
        }
        for (_, shape) in def.param_defs() {
            let len: usize = shape.iter().product::<usize>().max(1);
            lits.push(lit_f32(&shape, &vec![0.0; len]).unwrap());
        }
        assert_eq!(lits.len(), 3 * n);
        lits.push(lit_f32(&[], &[0.0]).unwrap());
        let mut hypers = super::super::HYPERS_DEFAULT.to_vec();
        hypers[super::super::HYP_LR] = lr;
        lits.push(lit_f32(&[11], &hypers).unwrap());
        let obs: Vec<u8> = (0..b * t * def.obs_len())
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        lits.push(lit_u8(&[b, t, 24, 32, 3], &obs).unwrap());
        let last: Vec<u8> = (0..b * def.obs_len())
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        lits.push(lit_u8(&[b, 24, 32, 3], &last).unwrap());
        lits.push(lit_f32(&[b, def.hidden], &vec![0.0; b * def.hidden]).unwrap());
        let acts: Vec<i32> = (0..b * t * def.n_heads()).map(|i| (i % 2) as i32).collect();
        lits.push(lit_i32(&[b, t, def.n_heads()], &acts).unwrap());
        lits.push(lit_f32(&[b, t], &vec![-1.8; b * t]).unwrap());
        let rew: Vec<f32> = (0..b * t).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        lits.push(lit_f32(&[b, t], &rew).unwrap());
        lits.push(lit_f32(&[b, t], &vec![0.0; b * t]).unwrap());
        (def, lits)
    }

    #[test]
    fn train_step_moves_params_and_reports_finite_metrics() {
        let (def, lits) = tiny_inputs(1e-3);
        let out = run_once(&def, &lits);
        let n = def.n_params();
        assert_eq!(out.len(), 3 * n + 2);
        let before = lits[0].as_f32().unwrap();
        let after = out[0].as_f32().unwrap();
        assert_ne!(before, after, "params did not move");
        let metrics = out[3 * n + 1].as_f32().unwrap();
        assert_eq!(metrics.len(), 8);
        assert!(metrics.iter().all(|m| m.is_finite()), "{metrics:?}");
        let gnorm = metrics[5];
        assert!(gnorm > 0.0);
        assert_eq!(out[3 * n].as_f32().unwrap().to_vec(), vec![1.0]);
    }

    #[test]
    fn zero_lr_is_identity_on_params() {
        let (def, lits) = tiny_inputs(0.0);
        let out = run_once(&def, &lits);
        for pi in 0..def.n_params() {
            let before = lits[pi].as_f32().unwrap();
            let after = out[pi].as_f32().unwrap();
            for (x, y) in before.iter().zip(after) {
                assert!((x - y).abs() < 1e-7, "param {pi} moved with lr=0");
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        // Two runs through the same TrainProgram (second reuses the first's
        // scratch buffers) must produce identical outputs.
        let (def, lits) = tiny_inputs(1e-3);
        let prog = TrainProgram::new(def.clone());
        let refs: Vec<&Literal> = lits.iter().collect();
        let out1 = prog.run(&refs).unwrap();
        let out2 = prog.run(&refs).unwrap();
        let n = def.n_params();
        for pi in 0..n {
            assert_eq!(
                out1[pi].as_f32().unwrap(),
                out2[pi].as_f32().unwrap(),
                "param {pi} differs across scratch reuse"
            );
        }
        assert_eq!(
            out1[3 * n + 1].as_f32().unwrap(),
            out2[3 * n + 1].as_f32().unwrap(),
            "metrics differ across scratch reuse"
        );
    }

    #[test]
    fn logits_gradient_matches_finite_difference() {
        // The per-row d_logits formula (log-prob + entropy terms) is pure
        // and stop-gradient-free, so it has a clean numeric oracle.
        let heads = [3usize, 2];
        let actions = [1usize, 0];
        let (w_lp, w_ent) = (0.7f32, -0.3f32);
        let loss = |logits: &[f32]| -> f32 {
            let mut lsm = [0.0f32; 3];
            let (mut lp, mut ent) = (0.0f32, 0.0f32);
            let mut off = 0;
            for (hd, &hn) in heads.iter().enumerate() {
                crate::util::log_softmax(&logits[off..off + hn], &mut lsm[..hn]);
                lp += lsm[actions[hd]];
                for &l in &lsm[..hn] {
                    ent -= l.exp() * l;
                }
                off += hn;
            }
            w_lp * lp + w_ent * ent
        };
        let mut logits = [0.4f32, -0.2, 1.1, 0.9, -0.5];
        // Analytic: d/dl_j = w_lp*(1{j=a} - p_j) - w_ent*p_j*(log p_j + H).
        let mut analytic = [0.0f32; 5];
        let mut lsm = [0.0f32; 3];
        let mut off = 0;
        for (hd, &hn) in heads.iter().enumerate() {
            crate::util::log_softmax(&logits[off..off + hn], &mut lsm[..hn]);
            let mut h_head = 0.0f32;
            for &l in &lsm[..hn] {
                h_head -= l.exp() * l;
            }
            for j in 0..hn {
                let p = lsm[j].exp();
                let ind = if j == actions[hd] { 1.0 } else { 0.0 };
                analytic[off + j] =
                    w_lp * (ind - p) - w_ent * p * (lsm[j] + h_head);
            }
            off += hn;
        }
        for j in 0..5 {
            let eps = 1e-3f32;
            let orig = logits[j];
            logits[j] = orig + eps;
            let up = loss(&logits);
            logits[j] = orig - eps;
            let down = loss(&logits);
            logits[j] = orig;
            let numeric = (up - down) / (2.0 * eps);
            assert!(
                (numeric - analytic[j]).abs() < 1e-3,
                "logit {j}: fd {numeric} vs analytic {analytic:?}"
            );
        }
    }

    #[test]
    fn repeated_steps_fit_the_value_function() {
        // End-to-end descent check: iterating the train step on a fixed
        // batch must drive the value loss down (the full gradient path
        // conv -> fc -> GRU BPTT -> value head is exercised).  gamma = 0
        // makes the V-trace targets quasi-stationary (values regress toward
        // the immediate rewards), so the fit is monotone-ish and collapses
        // ~100x in 40 steps; asserting 0.3 leaves a wide margin.  The same
        // experiment cross-checked against a NumPy mirror validated by
        // jax.grad of python/compile/model.py::appo_loss.
        let (def, mut lits) = tiny_inputs(2e-3);
        let n = def.n_params();
        {
            let mut hypers = super::super::HYPERS_DEFAULT.to_vec();
            hypers[super::super::HYP_LR] = 2e-3;
            hypers[super::super::HYP_GAMMA] = 0.0;
            hypers[super::super::HYP_ENT] = 0.0;
            lits[3 * n + 1] = lit_f32(&[11], &hypers).unwrap();
        }
        let prog = TrainProgram::new(def.clone());
        let mut head = 0.0f32;
        let mut tail = 0.0f32;
        let steps = 40;
        for it in 0..steps {
            let refs: Vec<&Literal> = lits.iter().collect();
            let out = prog.run(&refs).unwrap();
            drop(refs);
            let metrics = out[3 * n + 1].as_f32().unwrap();
            assert!(metrics.iter().all(|m| m.is_finite()), "step {it}: {metrics:?}");
            let v_loss = metrics[2];
            if it < 3 {
                head += v_loss / 3.0;
            }
            if it >= steps - 5 {
                tail += v_loss / 5.0;
            }
            // Feed params/m/v/step back in for the next iteration.
            for (i, lit) in out.into_iter().take(3 * n + 1).enumerate() {
                lits[i] = lit;
            }
        }
        assert!(
            tail < head * 0.3,
            "value loss did not descend: head {head}, tail {tail}"
        );
    }
}
