//! The native `train` program: one fused APPO SGD step, mirroring
//! `python/compile/model.py::train_step` —
//!
//! 1. forward over the (B, T) trajectory batch with BPTT through the GRU,
//! 2. V-trace off-policy correction (`kernels/ref.py::vtrace_ref`, rho_bar =
//!    c_bar = 1 as in Table A.5) with stop-gradient targets,
//! 3. PPO-clipped policy gradient on normalised V-trace advantages +
//!    value regression + entropy bonus,
//! 4. analytic backprop (heads -> GRU BPTT -> fc/conv encoder; the conv
//!    activations are recomputed per frame — activation checkpointing —
//!    so memory stays O(one frame) instead of O(B*T frames)),
//! 5. global-norm gradient clipping and an in-step bias-corrected Adam
//!    update.
//!
//! Inputs:  params[n] | m[n] | v[n] | step | hypers | obs(B,T,H,W,C) u8 |
//!          last_obs(B,H,W,C) u8 | h0(B,hid) | actions(B,T,heads) i32 |
//!          behavior_lp(B,T) | rewards(B,T) | dones(B,T)
//! Outputs: params'[n] | m'[n] | v'[n] | step' | metrics[8]
//!
//! The gradient of the bootstrap branch (`last_obs` encoder + final GRU
//! step) is exactly zero because `v_boot` is stop-gradient in the loss, so
//! that branch is forward-only here too.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::ops;
use super::{
    backward_frame, encode_frame, FrameActs, FrameGradScratch, Grads, ModelDef,
    ParamView, HYP_B1, HYP_B2, HYP_CLIP, HYP_ENT, HYP_EPS, HYP_GAMMA, HYP_LR,
    HYP_MAX_GN, HYP_VF,
};
use crate::runtime::{Literal, Program};

pub(crate) struct TrainProgram {
    pub def: Arc<ModelDef>,
}

impl Program for TrainProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        run_train(&self.def, inputs)
    }
}

/// Split three consecutive GRU parameter-grad buffers out of `grads`.
fn gru_grads<'a>(
    grads: &'a mut Grads,
    def: &ModelDef,
) -> (&'a mut [f32], &'a mut [f32], &'a mut [f32]) {
    let wx = def.idx_gru_wx();
    let (lo, rest) = grads.0.split_at_mut(wx + 1);
    let (mid, hi) = rest.split_at_mut(1);
    (&mut lo[wx], &mut mid[0], &mut hi[0])
}

#[allow(clippy::needless_range_loop)]
fn run_train(def: &ModelDef, inputs: &[&Literal]) -> Result<Vec<Literal>> {
    let n = def.n_params();
    if inputs.len() != 3 * n + 9 {
        return Err(anyhow!(
            "train takes {} inputs (3x{} params/m/v + step + hypers + 7 batch \
             tensors), got {}",
            3 * n + 9,
            n,
            inputs.len()
        ));
    }
    let pv = ParamView::parse(def, &inputs[..n])?;
    let m_in: Vec<&[f32]> = collect_f32(&inputs[n..2 * n])?;
    let v_in: Vec<&[f32]> = collect_f32(&inputs[2 * n..3 * n])?;
    let step_in = inputs[3 * n].as_f32()?[0];
    let hypers = inputs[3 * n + 1].as_f32()?;
    if hypers.len() <= HYP_EPS {
        return Err(anyhow!("train: hyper vector has {} entries", hypers.len()));
    }
    let obs = inputs[3 * n + 2].as_u8()?;
    let last_obs = inputs[3 * n + 3].as_u8()?;
    let h0 = inputs[3 * n + 4].as_f32()?;
    let actions = inputs[3 * n + 5].as_i32()?;
    let blp = inputs[3 * n + 6].as_f32()?;
    let rewards = inputs[3 * n + 7].as_f32()?;
    let dones = inputs[3 * n + 8].as_f32()?;

    let obs_dims = inputs[3 * n + 2].dims();
    if obs_dims.len() != 5 {
        return Err(anyhow!("train obs must be (B,T,H,W,C), got {obs_dims:?}"));
    }
    let (bsz, t_len) = (obs_dims[0], obs_dims[1]);
    let obs_len = def.obs_len();
    if [obs_dims[2], obs_dims[3], obs_dims[4]] != def.obs
        || obs.len() != bsz * t_len * obs_len
    {
        return Err(anyhow!("train obs geometry {obs_dims:?} != spec {:?}", def.obs));
    }
    let hid = def.hidden;
    let n_heads = def.n_heads();
    let ta = def.total_actions();
    let nbt = bsz * t_len;
    if last_obs.len() != bsz * obs_len
        || h0.len() != bsz * hid
        || actions.len() != nbt * n_heads
        || blp.len() != nbt
        || rewards.len() != nbt
        || dones.len() != nbt
    {
        return Err(anyhow!("train batch tensor sizes inconsistent with obs (B={bsz}, T={t_len})"));
    }

    let (gamma, clip) = (hypers[HYP_GAMMA], hypers[HYP_CLIP]);
    let (ent_coef, vf_coef) = (hypers[HYP_ENT], hypers[HYP_VF]);
    let inv_n = 1.0f32 / nbt as f32;

    // ---- 1. encode every frame (batch-major, like the obs tensor) --------
    let fc = def.fc_dim;
    let mut acts = FrameActs::new(def);
    let mut emb = vec![0.0f32; nbt * fc]; // [b*T + t]
    for i in 0..nbt {
        encode_frame(def, &pv, &obs[i * obs_len..(i + 1) * obs_len], &mut acts);
        emb[i * fc..(i + 1) * fc].copy_from_slice(&acts.emb);
    }
    let mut emb_last = vec![0.0f32; bsz * fc];
    for b in 0..bsz {
        encode_frame(def, &pv, &last_obs[b * obs_len..(b + 1) * obs_len], &mut acts);
        emb_last[b * fc..(b + 1) * fc].copy_from_slice(&acts.emb);
    }

    // ---- 2. GRU unroll with saved per-step traces (time-major) -----------
    // done *before* step t resets the hidden state (dones shifted right).
    let mut traces: Vec<ops::GruTrace> =
        (0..t_len * bsz).map(|_| ops::GruTrace::new(hid)).collect();
    let mut h_seq = vec![0.0f32; t_len * bsz * hid]; // [t*bsz + b]
    let mut gru_scratch = vec![0.0f32; 6 * hid];
    let mut h_masked = vec![0.0f32; hid];
    for t in 0..t_len {
        for b in 0..bsz {
            let mask = if t == 0 { 1.0 } else { 1.0 - dones[b * t_len + t - 1] };
            {
                let h_prev: &[f32] = if t == 0 {
                    &h0[b * hid..(b + 1) * hid]
                } else {
                    &h_seq[((t - 1) * bsz + b) * hid..((t - 1) * bsz + b + 1) * hid]
                };
                for (hm, &hp) in h_masked.iter_mut().zip(h_prev) {
                    *hm = hp * mask;
                }
            }
            let x = &emb[(b * t_len + t) * fc..(b * t_len + t + 1) * fc];
            let idx = t * bsz + b;
            // h_prev was already copied out into h_masked, so borrowing the
            // output row mutably is fine.
            let h_new = &mut h_seq[idx * hid..(idx + 1) * hid];
            ops::gru_forward_row(
                x, &h_masked, pv.gru_wx, pv.gru_wh, pv.gru_b, h_new, &mut gru_scratch,
                Some(&mut traces[idx]),
            );
        }
    }

    // ---- 3. heads + values over all cores ---------------------------------
    let mut logits = vec![0.0f32; t_len * bsz * ta]; // [t*bsz + b]
    let mut values = vec![0.0f32; t_len * bsz];
    let mut v1 = [0.0f32; 1];
    for i in 0..t_len * bsz {
        let core = &h_seq[i * hid..(i + 1) * hid];
        let row = &mut logits[i * ta..(i + 1) * ta];
        let mut off = 0usize;
        for hd in 0..n_heads {
            ops::linear_forward(core, pv.head_w[hd], pv.head_b[hd], &mut row[off..off + def.heads[hd]]);
            off += def.heads[hd];
        }
        ops::linear_forward(core, pv.value_w, pv.value_b, &mut v1);
        values[i] = v1[0];
    }

    // Bootstrap value for x_{T+1} (stop-gradient: forward only).
    let mut v_boot = vec![0.0f32; bsz];
    {
        let mut h_boot = vec![0.0f32; hid];
        for b in 0..bsz {
            let mask = 1.0 - dones[b * t_len + t_len - 1];
            let h_last = &h_seq[((t_len - 1) * bsz + b) * hid..((t_len - 1) * bsz + b + 1) * hid];
            for (hm, &hp) in h_masked.iter_mut().zip(h_last) {
                *hm = hp * mask;
            }
            ops::gru_forward_row(
                &emb_last[b * fc..(b + 1) * fc],
                &h_masked,
                pv.gru_wx,
                pv.gru_wh,
                pv.gru_b,
                &mut h_boot,
                &mut gru_scratch,
                None,
            );
            ops::linear_forward(&h_boot, pv.value_w, pv.value_b, &mut v1);
            v_boot[b] = v1[0];
        }
    }

    // ---- 4. log-probs, entropy, importance ratios -------------------------
    // target_lp/entropy per (t, b); actions tensor is batch-major.
    let mut target_lp = vec![0.0f32; t_len * bsz];
    let mut entropy = vec![0.0f32; t_len * bsz];
    let max_head = *def.heads.iter().max().unwrap_or(&1);
    let mut lsm = vec![0.0f32; max_head];
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let row = &logits[i * ta..(i + 1) * ta];
            let a_row = &actions[(b * t_len + t) * n_heads..(b * t_len + t + 1) * n_heads];
            let (mut lp, mut ent) = (0.0f32, 0.0f32);
            let mut off = 0usize;
            for (hd, &hn) in def.heads.iter().enumerate() {
                crate::util::log_softmax(&row[off..off + hn], &mut lsm[..hn]);
                let a = a_row[hd];
                if a < 0 || a as usize >= hn {
                    return Err(anyhow!("train: action {a} out of range for head {hd} ({hn})"));
                }
                lp += lsm[a as usize];
                for &l in &lsm[..hn] {
                    ent -= l.exp() * l;
                }
                off += hn;
            }
            target_lp[i] = lp;
            entropy[i] = ent;
        }
    }

    // ---- 5. V-trace (rho_bar = c_bar = 1, Table A.5) ----------------------
    let mut rho_c = vec![0.0f32; t_len * bsz];
    let mut vs = vec![0.0f32; t_len * bsz];
    let mut adv = vec![0.0f32; t_len * bsz];
    for b in 0..bsz {
        let mut acc = 0.0f32;
        for t in (0..t_len).rev() {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let rho = (target_lp[i] - blp[bt]).exp();
            let rc = rho.min(1.0);
            let cc = rho.min(1.0);
            rho_c[i] = rc;
            let disc = gamma * (1.0 - dones[bt]);
            let v_tp1 = if t + 1 == t_len { v_boot[b] } else { values[(t + 1) * bsz + b] };
            let delta = rc * (rewards[bt] + disc * v_tp1 - values[i]);
            acc = delta + disc * cc * acc;
            vs[i] = values[i] + acc;
        }
        for t in 0..t_len {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let disc = gamma * (1.0 - dones[bt]);
            let vs_tp1 = if t + 1 == t_len { v_boot[b] } else { vs[(t + 1) * bsz + b] };
            adv[i] = rho_c[i] * (rewards[bt] + disc * vs_tp1 - values[i]);
        }
    }

    // Advantage normalisation (standard APPO practice).
    let adv_mean = (adv.iter().map(|&x| x as f64).sum::<f64>() / nbt as f64) as f32;
    let adv_var = (adv
        .iter()
        .map(|&x| {
            let d = (x - adv_mean) as f64;
            d * d
        })
        .sum::<f64>()
        / nbt as f64) as f32;
    let adv_std = adv_var.sqrt();
    for a in adv.iter_mut() {
        *a = (*a - adv_mean) / (adv_std + 1e-5);
    }

    // ---- 6. losses + metrics ----------------------------------------------
    let (lo, hi) = (1.0 / (1.0 + clip), 1.0 + clip);
    let mut pg_loss = 0.0f64;
    let mut v_loss = 0.0f64;
    let mut ent_mean = 0.0f64;
    let mut approx_kl = 0.0f64;
    let mut mean_rho = 0.0f64;
    let mut mean_vs = 0.0f64;
    // d(total)/d(target_lp) and d(total)/d(values), filled in the same pass.
    let mut d_lp = vec![0.0f32; t_len * bsz];
    let mut d_values = vec![0.0f32; t_len * bsz];
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let bt = b * t_len + t;
            let ratio = (target_lp[i] - blp[bt]).exp();
            let t1 = ratio * adv[i];
            let t2 = ratio.clamp(lo, hi) * adv[i];
            let surr = t1.min(t2);
            pg_loss -= surr as f64;
            // d surr/d lp: the unclipped branch contributes ratio*adv (== t1);
            // a selected clipped branch is constant in lp.
            let d_surr = if t1 <= t2 { t1 } else { 0.0 };
            d_lp[i] = -inv_n * d_surr;
            let verr = values[i] - vs[i];
            v_loss += 0.5 * (verr * verr) as f64;
            d_values[i] = vf_coef * inv_n * verr;
            ent_mean += entropy[i] as f64;
            approx_kl += (blp[bt] - target_lp[i]) as f64;
            mean_rho += rho_c[i] as f64;
            mean_vs += vs[i] as f64;
        }
    }
    pg_loss /= nbt as f64;
    v_loss /= nbt as f64;
    ent_mean /= nbt as f64;
    approx_kl /= nbt as f64;
    mean_rho /= nbt as f64;
    mean_vs /= nbt as f64;
    let total = pg_loss + vf_coef as f64 * v_loss - ent_coef as f64 * ent_mean;

    // ---- 7. backprop into logits/values, then heads -> cores --------------
    let mut grads = Grads::new(def);
    let mut d_cores = vec![0.0f32; t_len * bsz * hid];
    let mut d_logits_row = vec![0.0f32; ta];
    for t in 0..t_len {
        for b in 0..bsz {
            let i = t * bsz + b;
            let row = &logits[i * ta..(i + 1) * ta];
            let a_row = &actions[(b * t_len + t) * n_heads..(b * t_len + t + 1) * n_heads];
            let mut off = 0usize;
            for (hd, &hn) in def.heads.iter().enumerate() {
                crate::util::log_softmax(&row[off..off + hn], &mut lsm[..hn]);
                let a = a_row[hd] as usize;
                // Head entropy (needed for dH/dl).
                let mut h_head = 0.0f32;
                for &l in &lsm[..hn] {
                    h_head -= l.exp() * l;
                }
                for j in 0..hn {
                    let p = lsm[j].exp();
                    let ind = if j == a { 1.0 } else { 0.0 };
                    // d total/d l_j = d_lp * (1{j=a} - p_j)
                    //               + ent_coef/N * p_j * (log p_j + H_head)
                    d_logits_row[off + j] = d_lp[i] * (ind - p)
                        + ent_coef * inv_n * p * (lsm[j] + h_head);
                }
                off += hn;
            }
            let core = &h_seq[i * hid..(i + 1) * hid];
            let d_core = &mut d_cores[i * hid..(i + 1) * hid];
            let mut off = 0usize;
            for (hd, &hn) in def.heads.iter().enumerate() {
                let (d_w, d_b) = grads.pair_mut(def.idx_head_w(hd), def.idx_head_b(hd));
                ops::linear_backward(
                    core,
                    pv.head_w[hd],
                    &d_logits_row[off..off + hn],
                    d_w,
                    d_b,
                    Some(&mut *d_core),
                );
                off += hn;
            }
            let (d_vw, d_vb) = grads.pair_mut(def.idx_value_w(), def.idx_value_b());
            ops::linear_backward(core, pv.value_w, &[d_values[i]], d_vw, d_vb, Some(&mut *d_core));
        }
    }

    // ---- 8. BPTT through the GRU ------------------------------------------
    let mut d_emb = vec![0.0f32; nbt * fc];
    let mut dh_carry = vec![0.0f32; bsz * hid];
    let mut dh_t = vec![0.0f32; hid];
    let mut d_h_prev = vec![0.0f32; hid];
    for t in (0..t_len).rev() {
        for b in 0..bsz {
            let i = t * bsz + b;
            {
                let carry = &dh_carry[b * hid..(b + 1) * hid];
                let dc = &d_cores[i * hid..(i + 1) * hid];
                for k in 0..hid {
                    dh_t[k] = carry[k] + dc[k];
                }
            }
            let x = &emb[(b * t_len + t) * fc..(b * t_len + t + 1) * fc];
            let dx = &mut d_emb[(b * t_len + t) * fc..(b * t_len + t + 1) * fc];
            let (d_wx, d_wh, d_b) = gru_grads(&mut grads, def);
            ops::gru_backward_row(
                x,
                &traces[i],
                pv.gru_wx,
                pv.gru_wh,
                &dh_t,
                dx,
                &mut d_h_prev,
                d_wx,
                d_wh,
                d_b,
                &mut gru_scratch,
            );
            // Through the done-reset mask into the *raw* h_{t-1}.
            let mask = if t == 0 { 1.0 } else { 1.0 - dones[b * t_len + t - 1] };
            let carry = &mut dh_carry[b * hid..(b + 1) * hid];
            for k in 0..hid {
                carry[k] = d_h_prev[k] * mask;
            }
        }
    }
    // dh_carry now holds d/d h0 — unused (h0 is an input, not a parameter).

    // ---- 9. encoder backward, frame by frame (recomputed activations) ----
    let mut fscratch = FrameGradScratch::new(def);
    let mut d_emb_row = vec![0.0f32; fc];
    for i in 0..nbt {
        let de = &d_emb[i * fc..(i + 1) * fc];
        if de.iter().all(|&v| v == 0.0) {
            continue;
        }
        d_emb_row.copy_from_slice(de);
        encode_frame(def, &pv, &obs[i * obs_len..(i + 1) * obs_len], &mut acts);
        backward_frame(def, &pv, &acts, &mut d_emb_row, &mut grads, &mut fscratch);
    }

    // ---- 10. global-norm clip + Adam --------------------------------------
    let gnorm = grads.global_norm();
    let max_gn = hypers[HYP_MAX_GN];
    if gnorm > max_gn {
        grads.scale(max_gn / gnorm);
    }

    let (b1, b2) = (hypers[HYP_B1], hypers[HYP_B2]);
    let (eps, lr) = (hypers[HYP_EPS], hypers[HYP_LR]);
    let new_step = step_in + 1.0;
    let bc1 = 1.0 - b1.powf(new_step);
    let bc2 = 1.0 - b2.powf(new_step);
    let defs = def.param_defs();
    let mut out: Vec<Literal> = Vec::with_capacity(3 * n + 2);
    let mut new_m_all: Vec<Vec<f32>> = Vec::with_capacity(n);
    let mut new_v_all: Vec<Vec<f32>> = Vec::with_capacity(n);
    for (pi, (_, shape)) in defs.iter().enumerate() {
        let p = pv_flat(&pv, def, pi);
        let g = &grads.0[pi];
        let (m0, v0) = (m_in[pi], v_in[pi]);
        if m0.len() != p.len() || v0.len() != p.len() {
            return Err(anyhow!("train: optimizer state shape mismatch at param {pi}"));
        }
        let mut p_new = vec![0.0f32; p.len()];
        let mut m_new = vec![0.0f32; p.len()];
        let mut v_new = vec![0.0f32; p.len()];
        for j in 0..p.len() {
            let m2 = b1 * m0[j] + (1.0 - b1) * g[j];
            let v2 = b2 * v0[j] + (1.0 - b2) * g[j] * g[j];
            let upd = lr * (m2 / bc1) / ((v2 / bc2).sqrt() + eps);
            p_new[j] = p[j] - upd;
            m_new[j] = m2;
            v_new[j] = v2;
        }
        out.push(Literal::f32(shape, p_new)?);
        new_m_all.push(m_new);
        new_v_all.push(v_new);
    }
    for (pi, data) in new_m_all.into_iter().enumerate() {
        out.push(Literal::f32(&defs[pi].1, data)?);
    }
    for (pi, data) in new_v_all.into_iter().enumerate() {
        out.push(Literal::f32(&defs[pi].1, data)?);
    }
    out.push(Literal::f32(&[], vec![new_step])?);
    let metrics = vec![
        total as f32,
        pg_loss as f32,
        v_loss as f32,
        ent_mean as f32,
        approx_kl as f32,
        gnorm,
        mean_rho as f32,
        mean_vs as f32,
    ];
    out.push(Literal::f32(&[8], metrics)?);
    Ok(out)
}

/// Flat slice of parameter `pi` from the view (defs order).
fn pv_flat<'a>(pv: &ParamView<'a>, def: &ModelDef, pi: usize) -> &'a [f32] {
    let nc = def.conv.len();
    if pi < 2 * nc {
        let layer = pi / 2;
        if pi % 2 == 0 {
            pv.conv_w[layer]
        } else {
            pv.conv_b[layer]
        }
    } else if pi == def.idx_fc_w() {
        pv.fc_w
    } else if pi == def.idx_fc_b() {
        pv.fc_b
    } else if pi == def.idx_gru_wx() {
        pv.gru_wx
    } else if pi == def.idx_gru_wh() {
        pv.gru_wh
    } else if pi == def.idx_gru_b() {
        pv.gru_b
    } else if pi == def.idx_value_w() {
        pv.value_w
    } else if pi == def.idx_value_b() {
        pv.value_b
    } else {
        let rel = pi - (def.idx_fc_w() + 5);
        let head = rel / 2;
        if rel % 2 == 0 {
            pv.head_w[head]
        } else {
            pv.head_b[head]
        }
    }
}

fn collect_f32<'a>(lits: &[&'a Literal]) -> Result<Vec<&'a [f32]>> {
    lits.iter().map(|l| l.as_f32()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_i32, lit_u32_scalar, lit_u8};

    /// Build a full input set for the tiny spec with a reproducible batch.
    fn tiny_inputs(lr: f32) -> (Arc<ModelDef>, Vec<Literal>) {
        let def = Arc::new(ModelDef::builtin("tiny").unwrap());
        let init = super::super::InitProgram { def: def.clone() };
        let seed = lit_u32_scalar(11);
        let params = init.run(&[&seed]).unwrap();
        let n = def.n_params();
        let (b, t) = (def.train_batch, def.rollout);
        let mut rng = crate::util::Rng::new(77);
        let mut lits: Vec<Literal> = Vec::new();
        lits.extend(params.iter().cloned());
        for (_, shape) in def.param_defs() {
            let len: usize = shape.iter().product::<usize>().max(1);
            lits.push(lit_f32(&shape, &vec![0.0; len]).unwrap());
        }
        for (_, shape) in def.param_defs() {
            let len: usize = shape.iter().product::<usize>().max(1);
            lits.push(lit_f32(&shape, &vec![0.0; len]).unwrap());
        }
        assert_eq!(lits.len(), 3 * n);
        lits.push(lit_f32(&[], &[0.0]).unwrap());
        let mut hypers = super::super::HYPERS_DEFAULT.to_vec();
        hypers[super::super::HYP_LR] = lr;
        lits.push(lit_f32(&[11], &hypers).unwrap());
        let obs: Vec<u8> = (0..b * t * def.obs_len())
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        lits.push(lit_u8(&[b, t, 24, 32, 3], &obs).unwrap());
        let last: Vec<u8> = (0..b * def.obs_len())
            .map(|_| (rng.next_u64() & 0xff) as u8)
            .collect();
        lits.push(lit_u8(&[b, 24, 32, 3], &last).unwrap());
        lits.push(lit_f32(&[b, def.hidden], &vec![0.0; b * def.hidden]).unwrap());
        let acts: Vec<i32> = (0..b * t * def.n_heads()).map(|i| (i % 2) as i32).collect();
        lits.push(lit_i32(&[b, t, def.n_heads()], &acts).unwrap());
        lits.push(lit_f32(&[b, t], &vec![-1.8; b * t]).unwrap());
        let rew: Vec<f32> = (0..b * t).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        lits.push(lit_f32(&[b, t], &rew).unwrap());
        lits.push(lit_f32(&[b, t], &vec![0.0; b * t]).unwrap());
        (def, lits)
    }

    #[test]
    fn train_step_moves_params_and_reports_finite_metrics() {
        let (def, lits) = tiny_inputs(1e-3);
        let refs: Vec<&Literal> = lits.iter().collect();
        let out = run_train(&def, &refs).unwrap();
        let n = def.n_params();
        assert_eq!(out.len(), 3 * n + 2);
        let before = lits[0].as_f32().unwrap();
        let after = out[0].as_f32().unwrap();
        assert_ne!(before, after, "params did not move");
        let metrics = out[3 * n + 1].as_f32().unwrap();
        assert_eq!(metrics.len(), 8);
        assert!(metrics.iter().all(|m| m.is_finite()), "{metrics:?}");
        let gnorm = metrics[5];
        assert!(gnorm > 0.0);
        assert_eq!(out[3 * n].as_f32().unwrap().to_vec(), vec![1.0]);
    }

    #[test]
    fn zero_lr_is_identity_on_params() {
        let (def, lits) = tiny_inputs(0.0);
        let refs: Vec<&Literal> = lits.iter().collect();
        let out = run_train(&def, &refs).unwrap();
        for pi in 0..def.n_params() {
            let before = lits[pi].as_f32().unwrap();
            let after = out[pi].as_f32().unwrap();
            for (x, y) in before.iter().zip(after) {
                assert!((x - y).abs() < 1e-7, "param {pi} moved with lr=0");
            }
        }
    }

    #[test]
    fn logits_gradient_matches_finite_difference() {
        // The per-row d_logits formula (log-prob + entropy terms) is pure
        // and stop-gradient-free, so it has a clean numeric oracle.
        let heads = [3usize, 2];
        let actions = [1usize, 0];
        let (w_lp, w_ent) = (0.7f32, -0.3f32);
        let loss = |logits: &[f32]| -> f32 {
            let mut lsm = [0.0f32; 3];
            let (mut lp, mut ent) = (0.0f32, 0.0f32);
            let mut off = 0;
            for (hd, &hn) in heads.iter().enumerate() {
                crate::util::log_softmax(&logits[off..off + hn], &mut lsm[..hn]);
                lp += lsm[actions[hd]];
                for &l in &lsm[..hn] {
                    ent -= l.exp() * l;
                }
                off += hn;
            }
            w_lp * lp + w_ent * ent
        };
        let mut logits = [0.4f32, -0.2, 1.1, 0.9, -0.5];
        // Analytic: d/dl_j = w_lp*(1{j=a} - p_j) - w_ent*p_j*(log p_j + H).
        let mut analytic = [0.0f32; 5];
        let mut lsm = [0.0f32; 3];
        let mut off = 0;
        for (hd, &hn) in heads.iter().enumerate() {
            crate::util::log_softmax(&logits[off..off + hn], &mut lsm[..hn]);
            let mut h_head = 0.0f32;
            for &l in &lsm[..hn] {
                h_head -= l.exp() * l;
            }
            for j in 0..hn {
                let p = lsm[j].exp();
                let ind = if j == actions[hd] { 1.0 } else { 0.0 };
                analytic[off + j] =
                    w_lp * (ind - p) - w_ent * p * (lsm[j] + h_head);
            }
            off += hn;
        }
        for j in 0..5 {
            let eps = 1e-3f32;
            let orig = logits[j];
            logits[j] = orig + eps;
            let up = loss(&logits);
            logits[j] = orig - eps;
            let down = loss(&logits);
            logits[j] = orig;
            let numeric = (up - down) / (2.0 * eps);
            assert!(
                (numeric - analytic[j]).abs() < 1e-3,
                "logit {j}: fd {numeric} vs analytic {analytic:?}"
            );
        }
    }

    #[test]
    fn repeated_steps_fit_the_value_function() {
        // End-to-end descent check: iterating the train step on a fixed
        // batch must drive the value loss down (the full gradient path
        // conv -> fc -> GRU BPTT -> value head is exercised).  gamma = 0
        // makes the V-trace targets quasi-stationary (values regress toward
        // the immediate rewards), so the fit is monotone-ish and collapses
        // ~100x in 40 steps; asserting 0.3 leaves a wide margin.  The same
        // experiment cross-checked against a NumPy mirror validated by
        // jax.grad of python/compile/model.py::appo_loss.
        let (def, mut lits) = tiny_inputs(2e-3);
        let n = def.n_params();
        {
            let mut hypers = super::super::HYPERS_DEFAULT.to_vec();
            hypers[super::super::HYP_LR] = 2e-3;
            hypers[super::super::HYP_GAMMA] = 0.0;
            hypers[super::super::HYP_ENT] = 0.0;
            lits[3 * n + 1] = lit_f32(&[11], &hypers).unwrap();
        }
        let mut head = 0.0f32;
        let mut tail = 0.0f32;
        let steps = 40;
        for it in 0..steps {
            let refs: Vec<&Literal> = lits.iter().collect();
            let out = run_train(&def, &refs).unwrap();
            drop(refs);
            let metrics = out[3 * n + 1].as_f32().unwrap();
            assert!(metrics.iter().all(|m| m.is_finite()), "step {it}: {metrics:?}");
            let v_loss = metrics[2];
            if it < 3 {
                head += v_loss / 3.0;
            }
            if it >= steps - 5 {
                tail += v_loss / 5.0;
            }
            // Feed params/m/v/step back in for the next iteration.
            for (i, lit) in out.into_iter().take(3 * n + 1).enumerate() {
                lits[i] = lit;
            }
        }
        assert!(
            tail < head * 0.3,
            "value loss did not descend: head {head}, tail {tail}"
        );
    }
}
