//! The pure-Rust `native` runtime backend (cargo feature `native`, default).
//!
//! Implements the manifest contract — conv encoder forward, GRU core,
//! multi-discrete heads, value head, and the fused APPO/V-trace train step
//! with analytic gradients — directly on f32 slices, so the full system
//! builds and tests from a clean checkout with no Python, XLA, or artifacts
//! directory.  The model architecture, parameter ordering, initialisation
//! scheme, hyperparameter vector and metric layout all mirror
//! `python/compile/model.py` (the source of truth for the PJRT backend); the
//! built-in spec table below is the Rust twin of `model.SPECS`.
//!
//! Numerics note: training math follows `model.appo_loss`/`train_step`
//! exactly (V-trace per `kernels/ref.py`, PPO clipping, entropy bonus,
//! advantage normalisation, global-norm clip, bias-corrected Adam).  The
//! backward pass is hand-derived backprop — no finite differences on the
//! hot path (those appear only in unit tests, as the oracle).
//!
//! Compute engine: the hot paths are **batch-native** — conv layers run
//! as one im2col + cache-blocked GEMM over the whole inference/train
//! batch ([`gemm`]), sharded across a scoped thread pool ([`pool`],
//! `SF_NATIVE_THREADS` to pin).  The per-row scalar kernels in [`ops`]
//! remain the reference implementation; `rust/tests/prop_kernels.rs`
//! asserts the two paths agree to 1e-5 across every builtin geometry.

pub mod gemm;
pub mod ops;
pub mod pool;
pub mod quant;
mod train;

use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use super::manifest::{Manifest, ParamDef};
use super::{Backend, DeviceBuffers, Executable, HostCache, Literal, LoadedModel, Program};
use crate::config::InferenceDtype;
use crate::util::Rng;
use ops::ConvGeom;
use pool::NativePool;

/// Hyperparameter vector layout; mirrors `model.HYPER_NAMES` and is what
/// PBT mutates without recompilation.
pub const HYPER_NAMES: [&str; 11] = [
    "lr", "ent_coef", "ppo_clip", "rho_clip", "c_clip", "vf_coef", "gamma",
    "max_grad_norm", "adam_b1", "adam_b2", "adam_eps",
];

/// Paper defaults, Table A.5 (mirrors `model.DEFAULT_HYPERS`).
pub const HYPERS_DEFAULT: [f32; 11] =
    [1e-4, 0.003, 0.1, 1.0, 1.0, 0.5, 0.99, 4.0, 0.9, 0.999, 1e-6];

pub const METRIC_NAMES: [&str; 8] = [
    "total_loss", "pg_loss", "v_loss", "entropy", "approx_kl", "grad_norm",
    "mean_rho", "mean_vs",
];

// Hyper vector indices (see HYPER_NAMES).
pub(crate) const HYP_LR: usize = 0;
pub(crate) const HYP_ENT: usize = 1;
pub(crate) const HYP_CLIP: usize = 2;
pub(crate) const HYP_VF: usize = 5;
pub(crate) const HYP_GAMMA: usize = 6;
pub(crate) const HYP_MAX_GN: usize = 7;
pub(crate) const HYP_B1: usize = 8;
pub(crate) const HYP_B2: usize = 9;
pub(crate) const HYP_EPS: usize = 10;

/// One conv layer: (out channels, square kernel, stride), SAME padding.
#[derive(Clone, Copy, Debug)]
pub struct ConvSpec {
    pub out_ch: usize,
    pub k: usize,
    pub stride: usize,
}

const fn c(out_ch: usize, k: usize, stride: usize) -> ConvSpec {
    ConvSpec { out_ch, k, stride }
}

/// Static description of one spec's model, with resolved conv geometry.
#[derive(Clone, Debug)]
pub struct ModelDef {
    pub name: String,
    /// (H, W, C) uint8 pixels.
    pub obs: [usize; 3],
    pub heads: Vec<usize>,
    pub conv: Vec<ConvSpec>,
    pub fc_dim: usize,
    pub hidden: usize,
    pub policy_batch: usize,
    pub train_batch: usize,
    pub rollout: usize,
    /// Resolved per-layer geometry (derived from `obs` + `conv`).
    pub geoms: Vec<ConvGeom>,
    /// Flattened size of the last conv output (the fc input).
    pub flat: usize,
}

impl ModelDef {
    #[allow(clippy::too_many_arguments)]
    fn build(
        name: &str,
        obs: [usize; 3],
        heads: &[usize],
        conv: &[ConvSpec],
        fc_dim: usize,
        hidden: usize,
        policy_batch: usize,
        train_batch: usize,
        rollout: usize,
    ) -> ModelDef {
        let mut geoms = Vec::with_capacity(conv.len());
        let (mut h, mut w, mut ch) = (obs[0], obs[1], obs[2]);
        for cs in conv {
            let g = ConvGeom::same(h, w, ch, cs.out_ch, cs.k, cs.stride);
            h = g.h_out;
            w = g.w_out;
            ch = g.c_out;
            geoms.push(g);
        }
        ModelDef {
            name: name.to_string(),
            obs,
            heads: heads.to_vec(),
            conv: conv.to_vec(),
            fc_dim,
            hidden,
            policy_batch,
            train_batch,
            rollout,
            geoms,
            flat: h * w * ch,
        }
    }

    /// The built-in spec table — the Rust twin of `python model.SPECS`
    /// (resolutions/widths scaled to the 1-core testbed; ratios mirror the
    /// paper's setups).
    pub fn builtin(spec: &str) -> Result<ModelDef> {
        let doomish_conv = [c(16, 8, 4), c(32, 4, 2), c(32, 3, 2)];
        Ok(match spec {
            "tiny" => ModelDef::build(
                "tiny", [24, 32, 3], &[3, 2],
                &[c(8, 4, 2), c(8, 4, 2), c(8, 3, 1)],
                32, 32, 8, 4, 8,
            ),
            "doomish" => ModelDef::build(
                "doomish", [36, 64, 3], &[3, 3, 2, 21],
                &doomish_conv, 128, 128, 32, 16, 32,
            ),
            "doomish_full" => ModelDef::build(
                "doomish_full", [36, 64, 3], &[3, 3, 2, 2, 2, 8, 21],
                &doomish_conv, 128, 128, 32, 16, 32,
            ),
            "arcade" => ModelDef::build(
                "arcade", [84, 84, 4], &[4],
                &[c(16, 8, 4), c(32, 4, 2), c(32, 3, 1)],
                128, 128, 32, 16, 32,
            ),
            "gridlab" => ModelDef::build(
                "gridlab", [72, 96, 3], &[7],
                &doomish_conv, 128, 128, 32, 16, 32,
            ),
            other => return Err(anyhow!("native backend: unknown spec '{other}'")),
        })
    }

    pub fn obs_len(&self) -> usize {
        self.obs.iter().product()
    }

    pub fn total_actions(&self) -> usize {
        self.heads.iter().sum()
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    /// Ordered (name, shape) list — must match `python model.param_defs`.
    pub fn param_defs(&self) -> Vec<(String, Vec<usize>)> {
        let mut defs: Vec<(String, Vec<usize>)> = Vec::new();
        let mut ch = self.obs[2];
        for (i, cs) in self.conv.iter().enumerate() {
            defs.push((format!("conv{i}/w"), vec![cs.k, cs.k, ch, cs.out_ch]));
            defs.push((format!("conv{i}/b"), vec![cs.out_ch]));
            ch = cs.out_ch;
        }
        defs.push(("fc/w".into(), vec![self.flat, self.fc_dim]));
        defs.push(("fc/b".into(), vec![self.fc_dim]));
        defs.push(("gru/wx".into(), vec![self.fc_dim, 3 * self.hidden]));
        defs.push(("gru/wh".into(), vec![self.hidden, 3 * self.hidden]));
        defs.push(("gru/b".into(), vec![2, 3 * self.hidden]));
        for (i, &n) in self.heads.iter().enumerate() {
            defs.push((format!("head{i}/w"), vec![self.hidden, n]));
            defs.push((format!("head{i}/b"), vec![n]));
        }
        defs.push(("value/w".into(), vec![self.hidden, 1]));
        defs.push(("value/b".into(), vec![1]));
        defs
    }

    pub fn n_params(&self) -> usize {
        2 * self.conv.len() + 5 + 2 * self.heads.len() + 2
    }

    // Parameter indices in `param_defs` order.
    pub(crate) fn idx_conv_w(&self, i: usize) -> usize {
        2 * i
    }
    pub(crate) fn idx_conv_b(&self, i: usize) -> usize {
        2 * i + 1
    }
    pub(crate) fn idx_fc_w(&self) -> usize {
        2 * self.conv.len()
    }
    pub(crate) fn idx_fc_b(&self) -> usize {
        self.idx_fc_w() + 1
    }
    pub(crate) fn idx_gru_wx(&self) -> usize {
        self.idx_fc_w() + 2
    }
    pub(crate) fn idx_gru_wh(&self) -> usize {
        self.idx_fc_w() + 3
    }
    pub(crate) fn idx_gru_b(&self) -> usize {
        self.idx_fc_w() + 4
    }
    pub(crate) fn idx_head_w(&self, i: usize) -> usize {
        self.idx_fc_w() + 5 + 2 * i
    }
    pub(crate) fn idx_head_b(&self, i: usize) -> usize {
        self.idx_head_w(i) + 1
    }
    pub(crate) fn idx_value_w(&self) -> usize {
        self.idx_fc_w() + 5 + 2 * self.heads.len()
    }
    pub(crate) fn idx_value_b(&self) -> usize {
        self.idx_value_w() + 1
    }

    /// Synthesize the manifest this model satisfies (what `make artifacts`
    /// would have written for the PJRT path).
    pub fn manifest(&self) -> Manifest {
        let params: Vec<ParamDef> = self
            .param_defs()
            .into_iter()
            .map(|(name, shape)| ParamDef { name, shape })
            .collect();
        let n_params = params.len();
        debug_assert_eq!(n_params, self.n_params());
        Manifest {
            name: self.name.clone(),
            obs_shape: self.obs,
            action_heads: self.heads.clone(),
            hidden: self.hidden,
            policy_batch: self.policy_batch,
            train_batch: self.train_batch,
            rollout: self.rollout,
            params,
            n_params,
            hyper_names: HYPER_NAMES.iter().map(|s| s.to_string()).collect(),
            hypers_default: HYPERS_DEFAULT.to_vec(),
            metric_names: METRIC_NAMES.iter().map(|s| s.to_string()).collect(),
        }
    }
}

/// Borrowed views of every parameter tensor, validated against the def.
/// Public so the property tests can drive the reference path directly.
pub struct ParamView<'a> {
    pub conv_w: Vec<&'a [f32]>,
    pub conv_b: Vec<&'a [f32]>,
    pub fc_w: &'a [f32],
    pub fc_b: &'a [f32],
    pub gru_wx: &'a [f32],
    pub gru_wh: &'a [f32],
    pub gru_b: &'a [f32],
    pub head_w: Vec<&'a [f32]>,
    pub head_b: Vec<&'a [f32]>,
    pub value_w: &'a [f32],
    pub value_b: &'a [f32],
}

impl<'a> ParamView<'a> {
    /// Parse the first `def.n_params()` literals as the parameter set.
    pub fn parse(def: &ModelDef, lits: &[&'a Literal]) -> Result<ParamView<'a>> {
        let defs = def.param_defs();
        if lits.len() < defs.len() {
            return Err(anyhow!(
                "native: {} parameter tensors supplied, model needs {}",
                lits.len(),
                defs.len()
            ));
        }
        let mut flat: Vec<&'a [f32]> = Vec::with_capacity(defs.len());
        for (i, (name, shape)) in defs.iter().enumerate() {
            let data = lits[i].as_f32()?;
            let want: usize = shape.iter().product::<usize>().max(1);
            if data.len() != want {
                return Err(anyhow!(
                    "native: param '{name}' has {} elements, expected {want}",
                    data.len()
                ));
            }
            flat.push(data);
        }
        let nc = def.conv.len();
        Ok(ParamView {
            conv_w: (0..nc).map(|i| flat[def.idx_conv_w(i)]).collect(),
            conv_b: (0..nc).map(|i| flat[def.idx_conv_b(i)]).collect(),
            fc_w: flat[def.idx_fc_w()],
            fc_b: flat[def.idx_fc_b()],
            gru_wx: flat[def.idx_gru_wx()],
            gru_wh: flat[def.idx_gru_wh()],
            gru_b: flat[def.idx_gru_b()],
            head_w: (0..def.n_heads()).map(|i| flat[def.idx_head_w(i)]).collect(),
            head_b: (0..def.n_heads()).map(|i| flat[def.idx_head_b(i)]).collect(),
            value_w: flat[def.idx_value_w()],
            value_b: flat[def.idx_value_b()],
        })
    }
}

/// Per-frame encoder activations (reused across frames to avoid allocs).
/// `layers[0]` is the normalized input; `layers[i+1]` the post-relu output
/// of conv layer i; `emb` the post-relu fc output.
///
/// Part of the **scalar reference path** (see [`encode_frame`]): the
/// production forward runs batched ([`encode_batch`]); this row-level
/// twin is kept for the equivalence property tests.
pub struct FrameActs {
    pub layers: Vec<Vec<f32>>,
    pub emb: Vec<f32>,
}

impl FrameActs {
    pub fn new(def: &ModelDef) -> FrameActs {
        let mut layers = Vec::with_capacity(def.geoms.len() + 1);
        layers.push(vec![0.0; def.obs_len()]);
        for g in &def.geoms {
            layers.push(vec![0.0; g.out_len()]);
        }
        FrameActs { layers, emb: vec![0.0; def.fc_dim] }
    }
}

/// Conv encoder + fc projection for one u8 frame (`model.encode`) —
/// scalar reference twin of [`encode_batch`].
pub fn encode_frame(def: &ModelDef, pv: &ParamView, obs_u8: &[u8], acts: &mut FrameActs) {
    debug_assert_eq!(obs_u8.len(), def.obs_len());
    for (dst, &src) in acts.layers[0].iter_mut().zip(obs_u8) {
        *dst = src as f32 * (1.0 / 255.0);
    }
    for (i, g) in def.geoms.iter().enumerate() {
        let (prev, rest) = acts.layers.split_at_mut(i + 1);
        ops::conv_forward(g, &prev[i], pv.conv_w[i], pv.conv_b[i], &mut rest[0]);
        ops::relu(&mut rest[0]);
    }
    let last = def.geoms.len();
    ops::linear_forward(&acts.layers[last], pv.fc_w, pv.fc_b, &mut acts.emb);
    ops::relu(&mut acts.emb);
}

/// Scratch gradient buffers for [`backward_frame`].
pub struct FrameGradScratch {
    pub d_layers: Vec<Vec<f32>>,
}

impl FrameGradScratch {
    pub fn new(def: &ModelDef) -> FrameGradScratch {
        let mut d_layers = Vec::with_capacity(def.geoms.len() + 1);
        d_layers.push(vec![0.0; def.obs_len()]);
        for g in &def.geoms {
            d_layers.push(vec![0.0; g.out_len()]);
        }
        FrameGradScratch { d_layers }
    }
}

/// Backprop one frame's encoder: given `d_emb` (gradient wrt the post-relu
/// fc output, consumed/overwritten), accumulate conv/fc parameter grads
/// into `grads`.  The gradient wrt the input pixels is discarded.
/// Scalar reference twin of [`backward_batch`].
pub fn backward_frame(
    def: &ModelDef,
    pv: &ParamView,
    acts: &FrameActs,
    d_emb: &mut [f32],
    grads: &mut Grads,
    scratch: &mut FrameGradScratch,
) {
    // Relu mask on the fc output.
    for (d, &a) in d_emb.iter_mut().zip(&acts.emb) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
    let last = def.geoms.len();
    scratch.d_layers[last].iter_mut().for_each(|v| *v = 0.0);
    {
        let (d_fc_w, d_fc_b) = grads.pair_mut(def.idx_fc_w(), def.idx_fc_b());
        ops::linear_backward(
            &acts.layers[last],
            pv.fc_w,
            d_emb,
            d_fc_w,
            d_fc_b,
            Some(&mut scratch.d_layers[last]),
        );
    }
    for i in (0..def.geoms.len()).rev() {
        // Relu mask on this layer's output.
        let (d_prev, d_rest) = scratch.d_layers.split_at_mut(i + 1);
        let d_out = &mut d_rest[0];
        for (d, &a) in d_out.iter_mut().zip(&acts.layers[i + 1]) {
            if a <= 0.0 {
                *d = 0.0;
            }
        }
        let want_d_in = i > 0;
        if want_d_in {
            d_prev[i].iter_mut().for_each(|v| *v = 0.0);
        }
        let (d_w, d_b) = grads.pair_mut(def.idx_conv_w(i), def.idx_conv_b(i));
        ops::conv_backward(
            &def.geoms[i],
            &acts.layers[i],
            pv.conv_w[i],
            d_out,
            d_w,
            d_b,
            if want_d_in { Some(&mut d_prev[i]) } else { None },
        );
    }
}

/// Dense per-parameter gradient buffers in `param_defs` order.
pub struct Grads(pub Vec<Vec<f32>>);

impl Grads {
    pub fn new(def: &ModelDef) -> Grads {
        Grads(
            def.param_defs()
                .iter()
                .map(|(_, shape)| vec![0.0f32; shape.iter().product::<usize>().max(1)])
                .collect(),
        )
    }

    /// Two distinct gradient buffers at once (split borrows).
    pub fn pair_mut(&mut self, a: usize, b: usize) -> (&mut [f32], &mut [f32]) {
        assert!(a < b, "pair_mut needs a < b");
        let (lo, hi) = self.0.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    }

    pub fn global_norm(&self) -> f32 {
        let ss: f64 = self
            .0
            .iter()
            .flat_map(|g| g.iter())
            .map(|&v| (v as f64) * (v as f64))
            .sum();
        ((ss + 1e-12) as f32).sqrt()
    }

    pub fn scale(&mut self, s: f32) {
        for g in &mut self.0 {
            for v in g.iter_mut() {
                *v *= s;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Batch-native encoder (the production path)
// ---------------------------------------------------------------------------

/// Reusable buffers for [`encode_batch`]: the normalized input, every
/// conv layer's post-relu activations, the post-relu fc embedding, and
/// the shared im2col scratch.  All sized lazily, so one scratch serves
/// any batch size without reallocation in steady state.
#[derive(Default)]
pub struct EncScratch {
    /// `[nb, H*W*C]` normalized pixels (conv layer 0 input).
    pub xs: Vec<f32>,
    /// `acts[i]`: `[nb, out_len(i)]` post-relu output of conv layer i.
    pub acts: Vec<Vec<f32>>,
    /// `[nb, fc_dim]` post-relu fc output.
    pub emb: Vec<f32>,
    /// im2col packing buffer, shared across layers.
    pub cols: Vec<f32>,
}

/// Conv encoder + fc projection for `nb` u8 frames at once: each conv
/// layer is one im2col + GEMM over the whole batch, the fc projection a
/// single GEMM.  Equivalent to [`encode_frame`] per row (property-tested).
pub fn encode_batch(
    def: &ModelDef,
    pv: &ParamView,
    pool: &NativePool,
    obs_u8: &[u8],
    nb: usize,
    s: &mut EncScratch,
) {
    let obs_len = def.obs_len();
    debug_assert_eq!(obs_u8.len(), nb * obs_len);
    let EncScratch { xs, acts, emb, cols } = s;
    xs.resize(nb * obs_len, 0.0);
    for (dst, &src) in xs.iter_mut().zip(obs_u8) {
        *dst = src as f32 * (1.0 / 255.0);
    }
    acts.resize(def.geoms.len(), Vec::new());
    for (i, g) in def.geoms.iter().enumerate() {
        let (prev, rest) = acts.split_at_mut(i);
        let inp: &[f32] = if i == 0 { xs.as_slice() } else { &prev[i - 1] };
        let out = &mut rest[0];
        out.resize(nb * g.out_len(), 0.0);
        gemm::conv_forward_batch(pool, g, nb, inp, pv.conv_w[i], pv.conv_b[i], cols, out);
        gemm::relu_batch(pool, out);
    }
    emb.resize(nb * def.fc_dim, 0.0);
    let last = &acts[def.geoms.len() - 1];
    gemm::gemm_nn(pool, nb, def.flat, def.fc_dim, last, pv.fc_w, Some(pv.fc_b), emb, false);
    gemm::relu_batch(pool, emb);
}

/// Zero the gradient wherever the forward activation was clamped by relu.
pub(crate) fn relu_mask(d: &mut [f32], a: &[f32]) {
    debug_assert_eq!(d.len(), a.len());
    for (dv, &av) in d.iter_mut().zip(a) {
        if av <= 0.0 {
            *dv = 0.0;
        }
    }
}

/// Per-call pre-transposed weights: input-gradient GEMMs (`dX = dY @ W^T`)
/// run through the vector-friendly NN path against these.  `conv_wt[0]`
/// stays empty — the pixel gradient is never needed.
pub struct WeightsT {
    pub conv_wt: Vec<Vec<f32>>,
    pub fc_wt: Vec<f32>,
}

impl WeightsT {
    pub fn build(def: &ModelDef, pv: &ParamView) -> WeightsT {
        let mut conv_wt = vec![Vec::new(); def.geoms.len()];
        for (i, g) in def.geoms.iter().enumerate().skip(1) {
            let krow = gemm::im2col_row_len(g);
            conv_wt[i] = vec![0.0f32; krow * g.c_out];
            gemm::transpose(pv.conv_w[i], krow, g.c_out, &mut conv_wt[i]);
        }
        let mut fc_wt = vec![0.0f32; def.flat * def.fc_dim];
        gemm::transpose(pv.fc_w, def.flat, def.fc_dim, &mut fc_wt);
        WeightsT { conv_wt, fc_wt }
    }
}

/// Gradient-side buffers for [`backward_batch`].
#[derive(Default)]
pub struct EncBwdScratch {
    d_cols: Vec<f32>,
    d_a: Vec<f32>,
    d_b: Vec<f32>,
}

/// Batched encoder backward: given `d_emb` (`[nb, fc]`, gradient wrt the
/// post-relu fc output; consumed/overwritten) and the *recomputed*
/// forward activations in `enc`, accumulate conv/fc parameter gradients
/// into `grads`.  dW and dX are GEMMs against the packed im2col buffer
/// (rebuilt per layer from the stored activations); the pixel gradient
/// is discarded.  Equivalent to [`backward_frame`] per row.
#[allow(clippy::too_many_arguments)] // full BPTT state; grouping would obscure the dataflow
pub fn backward_batch(
    def: &ModelDef,
    pv: &ParamView,
    wt: &WeightsT,
    pool: &NativePool,
    nb: usize,
    enc: &mut EncScratch,
    d_emb: &mut [f32],
    grads: &mut Grads,
    bwd: &mut EncBwdScratch,
) {
    debug_assert_eq!(d_emb.len(), nb * def.fc_dim);
    relu_mask(d_emb, &enc.emb);
    let nc = def.geoms.len();
    // fc: dW += flat^T d_emb ; db += colsum ; d_flat = d_emb @ fc_w^T.
    bwd.d_a.resize(nb * def.flat, 0.0);
    {
        let last = &enc.acts[nc - 1];
        let (d_fc_w, d_fc_b) = grads.pair_mut(def.idx_fc_w(), def.idx_fc_b());
        gemm::gemm_tn(pool, nb, def.flat, def.fc_dim, last, d_emb, d_fc_w);
        gemm::add_colsum(nb, def.fc_dim, d_emb, d_fc_b);
        gemm::gemm_nn(pool, nb, def.fc_dim, def.flat, d_emb, &wt.fc_wt, None, &mut bwd.d_a, false);
    }
    // Conv stack, last to first.  `d_a` holds the gradient wrt the
    // current layer's post-relu output; `d_b` receives the input grad.
    for i in (0..nc).rev() {
        let g = &def.geoms[i];
        relu_mask(&mut bwd.d_a[..nb * g.out_len()], &enc.acts[i]);
        let inp: &[f32] = if i == 0 { &enc.xs } else { &enc.acts[i - 1] };
        let want_d_in = i > 0;
        if want_d_in {
            bwd.d_b.resize(nb * g.in_len(), 0.0);
        }
        let (d_w, d_bias) = grads.pair_mut(def.idx_conv_w(i), def.idx_conv_b(i));
        gemm::conv_backward_batch(
            pool,
            g,
            nb,
            inp,
            if want_d_in { Some(&wt.conv_wt[i]) } else { None },
            &bwd.d_a[..nb * g.out_len()],
            &mut enc.cols,
            &mut bwd.d_cols,
            d_w,
            d_bias,
            if want_d_in { Some(&mut bwd.d_b[..nb * g.in_len()]) } else { None },
        );
        std::mem::swap(&mut bwd.d_a, &mut bwd.d_b);
    }
}

/// Pack the `n_heads` policy heads and the value head into one
/// `(hidden, total_actions + 1)` weight matrix + bias so the output
/// layer of a batch is a single GEMM.  Column order: head 0 logits |
/// head 1 | ... | value (last column).
pub(crate) fn pack_heads_value(
    def: &ModelDef,
    pv: &ParamView,
    w_all: &mut Vec<f32>,
    b_all: &mut Vec<f32>,
) {
    let ta1 = def.total_actions() + 1;
    let hidden = def.hidden;
    w_all.resize(hidden * ta1, 0.0);
    b_all.resize(ta1, 0.0);
    for r in 0..hidden {
        let row = &mut w_all[r * ta1..][..ta1];
        let mut off = 0usize;
        for (hd, &hn) in def.heads.iter().enumerate() {
            row[off..off + hn].copy_from_slice(&pv.head_w[hd][r * hn..(r + 1) * hn]);
            off += hn;
        }
        row[off] = pv.value_w[r];
    }
    let mut off = 0usize;
    for (hd, &hn) in def.heads.iter().enumerate() {
        b_all[off..off + hn].copy_from_slice(pv.head_b[hd]);
        off += hn;
    }
    b_all[off] = pv.value_b[0];
}

/// The pure-Rust backend.
pub struct NativeBackend;

impl Backend for NativeBackend {
    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn load_model(&self, artifacts_dir: &str, spec: &str) -> Result<LoadedModel> {
        let def = Arc::new(ModelDef::builtin(spec)?);
        let manifest = def.manifest();
        // If a PJRT artifacts bundle exists for this spec, fail fast on
        // contract drift rather than silently training a different model.
        let man_path = std::path::Path::new(artifacts_dir)
            .join(spec)
            .join("manifest.json");
        if man_path.exists() {
            let disk = Manifest::load(&man_path)?;
            let params_match = disk.params.len() == manifest.params.len()
                && disk
                    .params
                    .iter()
                    .zip(&manifest.params)
                    .all(|(a, b)| a.name == b.name && a.shape == b.shape);
            if disk.obs_shape != manifest.obs_shape
                || disk.action_heads != manifest.action_heads
                || disk.hidden != manifest.hidden
                || disk.train_batch != manifest.train_batch
                || disk.rollout != manifest.rollout
                || !params_match
                || disk.hyper_names != manifest.hyper_names
                || disk.metric_names != manifest.metric_names
            {
                return Err(anyhow!(
                    "artifacts manifest {man_path:?} disagrees with the native \
                     spec table for '{spec}' — stale `make artifacts` output?"
                ));
            }
        }
        Ok(LoadedModel {
            manifest,
            init: Executable::new(
                format!("native:{spec}/init"),
                Box::new(InitProgram { def: def.clone() }),
            ),
            policy: Executable::new(
                format!("native:{spec}/policy"),
                Box::new(PolicyProgram::new(def.clone())),
            ),
            train: Executable::new(
                format!("native:{spec}/train"),
                Box::new(train::TrainProgram::new(def)),
            ),
        })
    }

    /// The native backend's quantized serving path: f16/i8 swap in a
    /// [`PolicyProgram`] whose `upload` quantizes the published
    /// parameters once per version and whose `run_cached` runs the
    /// reduced-precision forward.  `init`/`train` (and the plain
    /// `policy.run`, used by the `SF_NO_PARAM_CACHE` ablation) stay f32.
    fn load_model_with(
        &self,
        artifacts_dir: &str,
        spec: &str,
        dtype: InferenceDtype,
    ) -> Result<LoadedModel> {
        let mut lm = self.load_model(artifacts_dir, spec)?;
        if dtype != InferenceDtype::F32 {
            let def = Arc::new(ModelDef::builtin(spec)?);
            lm.policy = Executable::new(
                format!("native:{spec}/policy[{}]", dtype.name()),
                Box::new(PolicyProgram::with_dtype(def, dtype)),
            );
        }
        Ok(lm)
    }
}

/// `init`: u32 seed -> fresh parameters (He-style init, zero biases,
/// small-scale head init; mirrors `model.init_params`).
struct InitProgram {
    def: Arc<ModelDef>,
}

impl Program for InitProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        if inputs.len() != 1 {
            return Err(anyhow!("init takes exactly the seed, got {} inputs", inputs.len()));
        }
        let seed = inputs[0].as_u32()?[0];
        let mut rng = Rng::new(0x5eed_0000_0000_0000 ^ seed as u64);
        let mut out = Vec::with_capacity(self.def.n_params());
        for (name, shape) in self.def.param_defs() {
            let n: usize = shape.iter().product::<usize>().max(1);
            let data: Vec<f32> = if name.ends_with("/b") {
                vec![0.0; n]
            } else if name.starts_with("head") {
                // Small-scale policy head init stabilises early training.
                (0..n).map(|_| 0.01 * rng.normal()).collect()
            } else {
                let fan_in: usize = shape[..shape.len() - 1].iter().product::<usize>().max(1);
                let scale = (2.0 / fan_in as f32).sqrt();
                (0..n).map(|_| scale * rng.normal()).collect()
            };
            out.push(Literal::f32(&shape, data)?);
        }
        Ok(out)
    }
}

/// Reusable scratch for one policy-program invocation.  Instances are
/// checked out of [`PolicyProgram::scratch`] so concurrent policy workers
/// each reuse their own buffers across batches (zero steady-state
/// allocation in the compute core).
#[derive(Default)]
struct PolicyScratch {
    enc: EncScratch,
    gx: Vec<f32>,
    gh: Vec<f32>,
    w_all: Vec<f32>,
    b_all: Vec<f32>,
    out_all: Vec<f32>,
    /// i8 path: quantized activations + per-row scales.
    a_q: Vec<i8>,
    a_scale: Vec<f32>,
    /// f16 path: per-layer weight decode panel.
    wf: Vec<f32>,
}

/// `policy`: params + u8 obs (B,H,W,C) + f32 h (B,hidden) ->
/// (logits (B,A), value (B), h' (B,hidden)).  Mirrors `model.policy_step`.
///
/// Batch-native: the conv encoder runs as im2col+GEMM over the whole
/// batch, the GRU gate projections and the heads+value output layer as
/// single GEMMs (heads and value are packed into one weight matrix).
struct PolicyProgram {
    def: Arc<ModelDef>,
    /// Serving dtype for the cached-parameter path
    /// (`upload`/`run_cached`); plain `run` is always f32.
    dtype: InferenceDtype,
    scratch: Mutex<Vec<PolicyScratch>>,
}

/// Pre-quantized parameter set built once per published version by
/// [`PolicyProgram::upload`]: every serving GEMM weight (conv stack via
/// im2col, fc, packed heads+value) in reduced precision, plus a full
/// f32 literal snapshot for the GRU step (recurrence stays f32 for
/// stability) and shape validation.
enum QuantPlan {
    I8 {
        conv: Vec<quant::QuantizedLinear>,
        fc: quant::QuantizedLinear,
        heads: quant::QuantizedLinear,
    },
    F16 {
        conv: Vec<quant::F16Matrix>,
        fc: quant::F16Matrix,
        heads: quant::F16Matrix,
        heads_bias: Vec<f32>,
    },
}

struct QuantCache {
    lits: Vec<Literal>,
    plan: QuantPlan,
}

impl PolicyProgram {
    fn new(def: Arc<ModelDef>) -> PolicyProgram {
        PolicyProgram::with_dtype(def, InferenceDtype::F32)
    }

    fn with_dtype(def: Arc<ModelDef>, dtype: InferenceDtype) -> PolicyProgram {
        PolicyProgram { def, dtype, scratch: Mutex::new(Vec::new()) }
    }

    /// Validate obs/h shapes against the def, returning the batch size.
    fn batch_of(&self, obs: &[u8], h_in: &[f32]) -> Result<usize> {
        let def = &*self.def;
        let obs_len = def.obs_len();
        if obs.len() % obs_len != 0 {
            return Err(anyhow!(
                "policy obs has {} bytes, not a multiple of frame size {obs_len}",
                obs.len()
            ));
        }
        let b = obs.len() / obs_len;
        if h_in.len() != b * def.hidden {
            return Err(anyhow!(
                "policy h has {} elements, expected {b} x {}",
                h_in.len(),
                def.hidden
            ));
        }
        Ok(b)
    }

    /// The full policy forward: encoder (f32 or quantized), f32 GRU,
    /// heads+value output layer (f32 or quantized).  `plan: None` is
    /// the exact f32 path `run` has always used.
    fn forward(
        &self,
        pv: &ParamView,
        plan: Option<&QuantPlan>,
        obs: &[u8],
        h_in: &[f32],
        b: usize,
    ) -> Result<Vec<Literal>> {
        let def = &*self.def;
        let hidden = def.hidden;
        let pool = NativePool::global();
        let mut s = self.scratch.lock().unwrap().pop().unwrap_or_default();

        // Encoder: conv stack + fc, whole batch at once.
        match plan {
            None => encode_batch(def, pv, pool, obs, b, &mut s.enc),
            Some(plan) => encode_batch_quant(def, pv, plan, pool, obs, b, &mut s),
        }

        // GRU step for all rows (two gate GEMMs + elementwise gates).
        let mut h_out = vec![0.0f32; b * hidden];
        gemm::gru_forward_batch(
            pool, b, def.fc_dim, hidden, &s.enc.emb, h_in, pv.gru_wx, pv.gru_wh,
            pv.gru_b, &mut h_out, &mut s.gx, &mut s.gh, None,
        );

        // Heads + value as one packed GEMM.
        let ta = def.total_actions();
        let ta1 = ta + 1;
        s.out_all.resize(b * ta1, 0.0);
        match plan {
            None => {
                pack_heads_value(def, pv, &mut s.w_all, &mut s.b_all);
                gemm::gemm_nn(
                    pool, b, hidden, ta1, &h_out, &s.w_all, Some(&s.b_all),
                    &mut s.out_all, false,
                );
            }
            Some(QuantPlan::I8 { heads, .. }) => quant::linear_i8_forward(
                pool, heads, b, &h_out, &mut s.a_q, &mut s.a_scale, &mut s.out_all,
            ),
            Some(QuantPlan::F16 { heads, heads_bias, .. }) => {
                heads.decode_into(&mut s.wf);
                gemm::gemm_nn(
                    pool, b, hidden, ta1, &h_out, &s.wf, Some(heads_bias),
                    &mut s.out_all, false,
                );
            }
        }
        let mut logits = vec![0.0f32; b * ta];
        let mut values = vec![0.0f32; b];
        for i in 0..b {
            logits[i * ta..(i + 1) * ta]
                .copy_from_slice(&s.out_all[i * ta1..i * ta1 + ta]);
            values[i] = s.out_all[i * ta1 + ta];
        }
        self.scratch.lock().unwrap().push(s);
        Ok(vec![
            Literal::f32(&[b, ta], logits)?,
            Literal::f32(&[b], values)?,
            Literal::f32(&[b, hidden], h_out)?,
        ])
    }
}

/// Quantized twin of [`encode_batch`]: identical structure (im2col +
/// one GEMM per conv layer, one fc GEMM, relu after each), with every
/// GEMM dispatched through the plan's reduced-precision weights.
fn encode_batch_quant(
    def: &ModelDef,
    pv: &ParamView,
    plan: &QuantPlan,
    pool: &NativePool,
    obs_u8: &[u8],
    nb: usize,
    s: &mut PolicyScratch,
) {
    let obs_len = def.obs_len();
    debug_assert_eq!(obs_u8.len(), nb * obs_len);
    let PolicyScratch { enc, a_q, a_scale, wf, .. } = s;
    let EncScratch { xs, acts, emb, cols } = enc;
    xs.resize(nb * obs_len, 0.0);
    for (dst, &src) in xs.iter_mut().zip(obs_u8) {
        *dst = src as f32 * (1.0 / 255.0);
    }
    acts.resize(def.geoms.len(), Vec::new());
    for (i, g) in def.geoms.iter().enumerate() {
        let (prev, rest) = acts.split_at_mut(i);
        let inp: &[f32] = if i == 0 { xs.as_slice() } else { &prev[i - 1] };
        let out = &mut rest[0];
        out.resize(nb * g.out_len(), 0.0);
        let krow = gemm::im2col_row_len(g);
        let m = nb * g.h_out * g.w_out;
        cols.resize(m * krow, 0.0);
        gemm::im2col(pool, g, nb, inp, cols);
        match plan {
            QuantPlan::I8 { conv, .. } => {
                quant::linear_i8_forward(pool, &conv[i], m, cols, a_q, a_scale, out)
            }
            QuantPlan::F16 { conv, .. } => {
                conv[i].decode_into(wf);
                gemm::gemm_nn(pool, m, krow, g.c_out, cols, wf, Some(pv.conv_b[i]), out, false);
            }
        }
        gemm::relu_batch(pool, out);
    }
    emb.resize(nb * def.fc_dim, 0.0);
    let last = &acts[def.geoms.len() - 1];
    match plan {
        QuantPlan::I8 { fc, .. } => {
            quant::linear_i8_forward(pool, fc, nb, last, a_q, a_scale, emb)
        }
        QuantPlan::F16 { fc, .. } => {
            fc.decode_into(wf);
            gemm::gemm_nn(pool, nb, def.flat, def.fc_dim, last, wf, Some(pv.fc_b), emb, false);
        }
    }
    gemm::relu_batch(pool, emb);
}

impl Program for PolicyProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let def = &*self.def;
        let n = def.n_params();
        if inputs.len() != n + 2 {
            return Err(anyhow!(
                "policy takes params + obs + h ({} inputs), got {}",
                n + 2,
                inputs.len()
            ));
        }
        let pv = ParamView::parse(def, &inputs[..n])?;
        let obs = inputs[n].as_u8()?;
        let h_in = inputs[n + 1].as_f32()?;
        let b = self.batch_of(obs, h_in)?;
        self.forward(&pv, None, obs, h_in, b)
    }

    fn upload(&self, inputs: &[&Literal]) -> Result<DeviceBuffers> {
        let lits: Vec<Literal> = inputs.iter().map(|l| (*l).clone()).collect();
        if self.dtype == InferenceDtype::F32 {
            return Ok(DeviceBuffers::new(HostCache(lits)));
        }
        let def = &*self.def;
        let refs: Vec<&Literal> = lits.iter().collect();
        let pv = ParamView::parse(def, &refs)?;
        let (mut w_all, mut b_all) = (Vec::new(), Vec::new());
        pack_heads_value(def, &pv, &mut w_all, &mut b_all);
        let ta1 = def.total_actions() + 1;
        let plan = match self.dtype {
            InferenceDtype::I8 => QuantPlan::I8 {
                conv: def
                    .geoms
                    .iter()
                    .enumerate()
                    .map(|(i, g)| {
                        quant::QuantizedLinear::from_f32(
                            pv.conv_w[i],
                            pv.conv_b[i],
                            gemm::im2col_row_len(g),
                            g.c_out,
                        )
                    })
                    .collect(),
                fc: quant::QuantizedLinear::from_f32(pv.fc_w, pv.fc_b, def.flat, def.fc_dim),
                heads: quant::QuantizedLinear::from_f32(&w_all, &b_all, def.hidden, ta1),
            },
            InferenceDtype::F16 => QuantPlan::F16 {
                conv: def
                    .geoms
                    .iter()
                    .enumerate()
                    .map(|(i, g)| {
                        quant::F16Matrix::from_f32(pv.conv_w[i], gemm::im2col_row_len(g), g.c_out)
                    })
                    .collect(),
                fc: quant::F16Matrix::from_f32(pv.fc_w, def.flat, def.fc_dim),
                heads: quant::F16Matrix::from_f32(&w_all, def.hidden, ta1),
                heads_bias: b_all,
            },
            InferenceDtype::F32 => unreachable!("handled above"),
        };
        Ok(DeviceBuffers::new(QuantCache { lits, plan }))
    }

    fn run_cached(&self, cached: &DeviceBuffers, fresh: &[&Literal]) -> Result<Vec<Literal>> {
        if let Some(host) = cached.downcast_ref::<HostCache>() {
            let mut refs: Vec<&Literal> = Vec::with_capacity(host.0.len() + fresh.len());
            refs.extend(host.0.iter());
            refs.extend_from_slice(fresh);
            return self.run(&refs);
        }
        let qc = cached
            .downcast_ref::<QuantCache>()
            .ok_or_else(|| anyhow!("input cache was created by a different backend"))?;
        if fresh.len() != 2 {
            return Err(anyhow!("quantized policy expects obs + h, got {} inputs", fresh.len()));
        }
        let def = &*self.def;
        let refs: Vec<&Literal> = qc.lits.iter().collect();
        let pv = ParamView::parse(def, &refs)?;
        let obs = fresh[0].as_u8()?;
        let h_in = fresh[1].as_f32()?;
        let b = self.batch_of(obs, h_in)?;
        self.forward(&pv, Some(&qc.plan), obs, h_in, b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, lit_u8};

    #[test]
    fn builtin_specs_match_env_tables() {
        for spec in ["tiny", "doomish", "doomish_full", "arcade", "gridlab"] {
            let def = ModelDef::builtin(spec).unwrap();
            let obs = crate::env::obs_for_spec(spec).unwrap();
            assert_eq!(def.obs, [obs.h, obs.w, obs.c], "{spec} obs drifted");
            assert_eq!(
                def.heads,
                crate::env::heads_for_spec(spec).unwrap(),
                "{spec} heads drifted"
            );
        }
        assert!(ModelDef::builtin("nope").is_err());
    }

    #[test]
    fn manifest_roundtrips_through_parser() {
        // The synthesized manifest must satisfy the same invariants the
        // JSON parser enforces for PJRT bundles.
        let man = ModelDef::builtin("tiny").unwrap().manifest();
        assert_eq!(man.params.len(), man.n_params);
        assert_eq!(man.hyper_names.len(), man.hypers_default.len());
        assert_eq!(man.total_actions(), 5);
        assert_eq!(man.hyper_index("lr"), Some(0));
        assert_eq!(man.metric_index("grad_norm"), Some(5));
    }

    #[test]
    fn tiny_flat_dim_matches_python() {
        // tiny: 24x32 -> 12x16 -> 6x8 -> 6x8 @ 8ch => flat 384.
        let def = ModelDef::builtin("tiny").unwrap();
        assert_eq!(def.flat, 6 * 8 * 8);
        let defs = def.param_defs();
        assert_eq!(defs[def.idx_fc_w()].1, vec![384, 32]);
        assert_eq!(defs[def.idx_gru_b()].1, vec![2, 96]);
        assert_eq!(defs.len(), def.n_params());
    }

    #[test]
    fn policy_program_shapes_and_determinism() {
        let def = Arc::new(ModelDef::builtin("tiny").unwrap());
        let init = InitProgram { def: def.clone() };
        let seed = Literal::u32_scalar(3);
        let params = init.run(&[&seed]).unwrap();
        let b = 2;
        let obs = lit_u8(&[b, 24, 32, 3], &vec![77u8; b * def.obs_len()]).unwrap();
        let h = lit_f32(&[b, def.hidden], &vec![0.0; b * def.hidden]).unwrap();
        let pol = PolicyProgram::new(def.clone());
        let mut inputs: Vec<&Literal> = params.iter().collect();
        inputs.push(&obs);
        inputs.push(&h);
        let out = pol.run(&inputs).unwrap();
        assert_eq!(out.len(), 3);
        let logits = out[0].as_f32().unwrap();
        assert_eq!(logits.len(), b * 5);
        // Identical rows in -> identical rows out.
        assert_eq!(logits[..5], logits[5..10]);
        let h_new = out[2].as_f32().unwrap();
        assert!(h_new.iter().all(|v| v.abs() <= 1.0 + 1e-5));
    }

    #[test]
    fn quantized_policy_tracks_f32_and_plain_run_stays_exact() {
        let def = Arc::new(ModelDef::builtin("tiny").unwrap());
        let init = InitProgram { def: def.clone() };
        let seed = Literal::u32_scalar(7);
        let params = init.run(&[&seed]).unwrap();
        let b = 4;
        let mut rng = crate::util::Rng::new(9);
        let obs_data: Vec<u8> =
            (0..b * def.obs_len()).map(|_| rng.range_f32(0.0, 255.0) as u8).collect();
        let obs = lit_u8(&[b, 24, 32, 3], &obs_data).unwrap();
        let h_data: Vec<f32> =
            (0..b * def.hidden).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let h = lit_f32(&[b, def.hidden], &h_data).unwrap();
        let param_refs: Vec<&Literal> = params.iter().collect();
        let mut full: Vec<&Literal> = param_refs.clone();
        full.push(&obs);
        full.push(&h);

        let f32_prog = PolicyProgram::new(def.clone());
        let cache = f32_prog.upload(&param_refs).unwrap();
        let want = f32_prog.run_cached(&cache, &[&obs, &h]).unwrap();

        for dtype in [InferenceDtype::F16, InferenceDtype::I8] {
            let prog = PolicyProgram::with_dtype(def.clone(), dtype);
            // The cached (serving) path is quantized but must track f32.
            let cache = prog.upload(&param_refs).unwrap();
            let got = prog.run_cached(&cache, &[&obs, &h]).unwrap();
            for (wl, gl) in want.iter().zip(&got) {
                for (i, (&w, &g)) in
                    wl.as_f32().unwrap().iter().zip(gl.as_f32().unwrap()).enumerate()
                {
                    assert!(
                        (w - g).abs() <= 0.1,
                        "{}[{i}]: f32 {w} vs {} {g}",
                        "quantized output",
                        dtype.name()
                    );
                }
            }
            // Plain `run` must stay the exact f32 path (bit-identical).
            let exact = prog.run(&full).unwrap();
            let base = f32_prog.run(&full).unwrap();
            for (el, bl) in exact.iter().zip(&base) {
                assert_eq!(el.as_f32().unwrap(), bl.as_f32().unwrap());
            }
        }
    }
}
