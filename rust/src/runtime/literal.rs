//! The backend-agnostic host tensor exchanged with runtime programs.
//!
//! Both backends speak [`Literal`]: the native backend computes on its
//! slices directly; the PJRT backend converts to/from `xla::Literal` at the
//! execute boundary.  A `Literal` is plain owned memory (typed `Vec` +
//! row-major dims), so it is `Send + Sync` without any unsafe.

use anyhow::{anyhow, Result};

/// Element dtype of a [`Literal`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    U8,
    I32,
    U32,
}

/// A host tensor: row-major data + dims.  Scalars have empty dims.
#[derive(Clone)]
pub enum Literal {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    U8 { dims: Vec<usize>, data: Vec<u8> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
    U32 { dims: Vec<usize>, data: Vec<u32> },
}

fn check_len(what: &str, dims: &[usize], len: usize) -> Result<()> {
    let expect: usize = dims.iter().product::<usize>().max(1);
    if len != expect {
        return Err(anyhow!("{what}: {len} values for dims {dims:?}"));
    }
    Ok(())
}

impl Literal {
    pub fn f32(dims: &[usize], data: Vec<f32>) -> Result<Literal> {
        check_len("Literal::f32", dims, data.len())?;
        Ok(Literal::F32 { dims: dims.to_vec(), data })
    }

    pub fn u8(dims: &[usize], data: Vec<u8>) -> Result<Literal> {
        check_len("Literal::u8", dims, data.len())?;
        Ok(Literal::U8 { dims: dims.to_vec(), data })
    }

    pub fn i32(dims: &[usize], data: Vec<i32>) -> Result<Literal> {
        check_len("Literal::i32", dims, data.len())?;
        Ok(Literal::I32 { dims: dims.to_vec(), data })
    }

    pub fn u32_scalar(v: u32) -> Literal {
        Literal::U32 { dims: Vec::new(), data: vec![v] }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Literal::F32 { .. } => DType::F32,
            Literal::U8 { .. } => DType::U8,
            Literal::I32 { .. } => DType::I32,
            Literal::U32 { .. } => DType::U32,
        }
    }

    pub fn dims(&self) -> &[usize] {
        match self {
            Literal::F32 { dims, .. }
            | Literal::U8 { dims, .. }
            | Literal::I32 { dims, .. }
            | Literal::U32 { dims, .. } => dims,
        }
    }

    /// Number of elements (1 for scalars).
    pub fn element_count(&self) -> usize {
        self.dims().iter().product::<usize>().max(1)
    }

    /// Borrow the f32 contents, or error with the actual dtype.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Literal::F32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected f32 literal, got {:?}", other.dtype())),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self {
            Literal::U8 { data, .. } => Ok(data),
            other => Err(anyhow!("expected u8 literal, got {:?}", other.dtype())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Literal::I32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected i32 literal, got {:?}", other.dtype())),
        }
    }

    pub fn as_u32(&self) -> Result<&[u32]> {
        match self {
            Literal::U32 { data, .. } => Ok(data),
            other => Err(anyhow!("expected u32 literal, got {:?}", other.dtype())),
        }
    }

    /// Copy out as a typed `Vec` (xla-rs-compatible call shape).
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::slice_of(self).map(|s| s.to_vec())
    }

    /// Copy into an existing buffer without allocating.
    pub fn copy_raw_to<T: Element>(&self, out: &mut [T]) -> Result<()> {
        let src = T::slice_of(self)?;
        if out.len() != src.len() {
            return Err(anyhow!(
                "copy_raw_to: literal has {} elements, buffer {}",
                src.len(),
                out.len()
            ));
        }
        out.copy_from_slice(src);
        Ok(())
    }
}

impl std::fmt::Debug for Literal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Literal<{:?}>{:?}", self.dtype(), self.dims())
    }
}

/// Element types a [`Literal`] can hold (sealed by construction).
pub trait Element: Copy {
    fn slice_of(lit: &Literal) -> Result<&[Self]>;
}

impl Element for f32 {
    fn slice_of(lit: &Literal) -> Result<&[f32]> {
        lit.as_f32()
    }
}

impl Element for u8 {
    fn slice_of(lit: &Literal) -> Result<&[u8]> {
        lit.as_u8()
    }
}

impl Element for i32 {
    fn slice_of(lit: &Literal) -> Result<&[i32]> {
        lit.as_i32()
    }
}

impl Element for u32 {
    fn slice_of(lit: &Literal) -> Result<&[u32]> {
        lit.as_u32()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_and_counts() {
        let l = Literal::f32(&[2, 3], vec![0.0; 6]).unwrap();
        assert_eq!(l.dims(), &[2, 3]);
        assert_eq!(l.element_count(), 6);
        assert_eq!(l.dtype(), DType::F32);
        let s = Literal::u32_scalar(7);
        assert_eq!(s.element_count(), 1);
        assert_eq!(s.as_u32().unwrap(), &[7]);
    }

    #[test]
    fn dtype_mismatch_is_error() {
        let l = Literal::i32(&[2], vec![1, 2]).unwrap();
        assert!(l.as_f32().is_err());
        assert!(l.to_vec::<f32>().is_err());
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![1, 2]);
    }

    #[test]
    fn length_validation() {
        assert!(Literal::f32(&[2, 2], vec![1.0]).is_err());
        assert!(Literal::u8(&[3], vec![1, 2, 3]).is_ok());
    }
}
