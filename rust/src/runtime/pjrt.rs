//! The PJRT/XLA backend (cargo feature `pjrt`): loads the AOT artifacts
//! (HLO text + manifest, written by `python/compile` via `make artifacts`)
//! and executes them through the PJRT C API.  This is the only place the
//! `xla` crate is touched; Python never runs after `make artifacts`.
//!
//! HLO *text* is the interchange format (jax >= 0.5 emits 64-bit-id protos
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids — see
//! DESIGN.md / aot.py).
//!
//! Offline builds compile against the stub in `third_party/xla-stub`, which
//! fails at `PjRtClient::cpu()` with a pointer at the README; swap the path
//! dependency for the real xla-rs crate to execute this backend.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::{Backend, DeviceBuffers, Executable, Literal, LoadedModel, Manifest, Program};

/// Convert a host [`Literal`] into an `xla::Literal` (one pre-sized copy).
fn to_xla(lit: &Literal) -> Result<xla::Literal> {
    fn le_bytes<T: Copy, const W: usize>(xs: &[T], to_le: impl Fn(T) -> [u8; W]) -> Vec<u8> {
        let mut out = Vec::with_capacity(xs.len() * W);
        for &x in xs {
            out.extend_from_slice(&to_le(x));
        }
        out
    }
    let (ty, bytes): (xla::ElementType, Vec<u8>) = match lit {
        Literal::F32 { data, .. } => (xla::ElementType::F32, le_bytes(data, f32::to_le_bytes)),
        Literal::U8 { data, .. } => (xla::ElementType::U8, data.clone()),
        Literal::I32 { data, .. } => (xla::ElementType::S32, le_bytes(data, i32::to_le_bytes)),
        Literal::U32 { data, .. } => (xla::ElementType::U32, le_bytes(data, u32::to_le_bytes)),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, lit.dims(), &bytes)
        .map_err(|e| anyhow!("to_xla: {e:?}"))
}

/// Convert a program output back into a host [`Literal`] (all dtypes the
/// runtime exchanges pass through, like the pre-refactor path).
fn from_xla(lit: &xla::Literal) -> Result<Literal> {
    let shape = lit.array_shape().map_err(|e| anyhow!("array_shape: {e:?}"))?;
    let dims: Vec<usize> = shape.dims().to_vec();
    let read = |what: &str, e: xla::Error| anyhow!("read {what} output: {e:?}");
    match shape.element_type() {
        xla::ElementType::F32 => {
            Literal::f32(&dims, lit.to_vec::<f32>().map_err(|e| read("f32", e))?)
        }
        xla::ElementType::U8 => {
            Literal::u8(&dims, lit.to_vec::<u8>().map_err(|e| read("u8", e))?)
        }
        xla::ElementType::S32 => {
            Literal::i32(&dims, lit.to_vec::<i32>().map_err(|e| read("i32", e))?)
        }
        xla::ElementType::U32 => {
            let data = lit.to_vec::<u32>().map_err(|e| read("u32", e))?;
            if dims.is_empty() && data.len() == 1 {
                Ok(Literal::u32_scalar(data[0]))
            } else {
                Err(anyhow!("non-scalar u32 program output {dims:?} unsupported"))
            }
        }
    }
}

/// A PJRT CPU client; compiles HLO text into executables.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

// SAFETY: the PJRT CPU client is thread-safe (it backs multi-threaded
// jax/TF runtimes); we only compile through `&self`.  The raw pointer
// inside the crate's wrapper is the only reason it isn't auto-Send/Sync.
unsafe impl Send for PjrtBackend {}
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    /// Create the CPU PJRT client (the container has no accelerator).
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(PjrtBackend { client })
    }

    /// Load HLO text and compile it.
    fn load_hlo_text(&self, path: &Path) -> Result<PjrtProgram> {
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(PjrtProgram {
            exe,
            client: self.client.clone(),
            name: path.display().to_string(),
        })
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load_model(&self, artifacts_dir: &str, spec: &str) -> Result<LoadedModel> {
        let dir = Path::new(artifacts_dir).join(spec);
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for spec '{spec}'"))?;
        let init = self.load_hlo_text(&dir.join("init.hlo.txt"))?;
        let policy = self.load_hlo_text(&dir.join("policy.hlo.txt"))?;
        let train = self.load_hlo_text(&dir.join("train.hlo.txt"))?;
        Ok(LoadedModel {
            manifest,
            init: Executable::new(format!("pjrt:{spec}/init"), Box::new(init)),
            policy: Executable::new(format!("pjrt:{spec}/policy"), Box::new(policy)),
            train: Executable::new(format!("pjrt:{spec}/train"), Box::new(train)),
        })
    }
}

/// A compiled program.  All our programs are lowered with
/// `return_tuple=True`, so execution returns one tuple literal that we
/// decompose into the per-output literals.
struct PjrtProgram {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    name: String,
}

// SAFETY: PJRT loaded executables are documented thread-safe for Execute;
// we only call `execute_b` through `&self`.  The client handle inside is
// reference-counted on the C++ side.
unsafe impl Send for PjrtProgram {}
unsafe impl Sync for PjrtProgram {}

/// Device-resident input cache: the uploaded buffers plus the host
/// literals backing them.
///
/// IMPORTANT: the host literals must stay alive as long as the buffers —
/// PJRT's BufferFromHostLiteral may borrow the host memory until the
/// (async) transfer completes.
struct PjrtCache {
    bufs: Vec<xla::PjRtBuffer>,
    _host: Vec<xla::Literal>,
}

// SAFETY: device buffers are plain handles, thread-safe per the PJRT
// contract; the host literals are only kept alive, never aliased.
unsafe impl Send for PjrtCache {}
unsafe impl Sync for PjrtCache {}

impl PjrtProgram {
    /// Upload host literals to device buffers, keeping the host copies
    /// alive alongside.
    fn upload_all(&self, inputs: &[&Literal]) -> Result<(Vec<xla::Literal>, Vec<xla::PjRtBuffer>)> {
        let mut host = Vec::with_capacity(inputs.len());
        let mut bufs = Vec::with_capacity(inputs.len());
        for (i, l) in inputs.iter().enumerate() {
            let xl = to_xla(l)?;
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, &xl)
                    .map_err(|e| anyhow!("upload input {i} of {}: {e:?}", self.name))?,
            );
            host.push(xl);
        }
        Ok((host, bufs))
    }

    /// Dispatch on device buffers and decompose the tuple output.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal inputs): the crate's C++ shim uploads each input literal to
    /// a device buffer it `release()`s and never frees — a per-call leak of
    /// the whole input set (~hundreds of MB/min at our call rates).  We
    /// upload through `buffer_from_host_literal` so Rust owns the buffers
    /// (freed on drop) and dispatch via `execute_b`.
    fn exec(&self, refs: &[&xla::PjRtBuffer]) -> Result<Vec<Literal>> {
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let mut lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch outputs of {}: {e:?}", self.name))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple outputs of {}: {e:?}", self.name))?;
        parts.iter().map(from_xla).collect()
    }
}

impl Program for PjrtProgram {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        let (_host, bufs) = self.upload_all(inputs)?;
        self.exec(&bufs.iter().collect::<Vec<_>>())
    }

    fn upload(&self, inputs: &[&Literal]) -> Result<DeviceBuffers> {
        let (host, bufs) = self.upload_all(inputs)?;
        Ok(DeviceBuffers::new(PjrtCache { bufs, _host: host }))
    }

    fn run_cached(&self, cached: &DeviceBuffers, fresh: &[&Literal]) -> Result<Vec<Literal>> {
        let cache = cached
            .downcast_ref::<PjrtCache>()
            .ok_or_else(|| anyhow!("input cache was not created by the pjrt backend"))?;
        let (_host, fresh_bufs) = self.upload_all(fresh)?;
        let mut refs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(cache.bufs.len() + fresh_bufs.len());
        refs.extend(cache.bufs.iter());
        refs.extend(fresh_bufs.iter());
        self.exec(&refs)
    }
}
