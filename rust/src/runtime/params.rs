//! Versioned parameter publication: learner -> policy workers.
//!
//! The paper stores the master copy of the model in shared CUDA memory and
//! has policy workers copy it in <1 ms as soon as the learner publishes an
//! update (§3.4) — this is what keeps the *first* source of policy lag
//! (acting with stale weights) negligible.  The in-process analogue: the
//! learner swaps an `Arc<Vec<Literal>>` under an `RwLock`; policy workers
//! poll the version counter (one atomic load) every batch and clone the
//! `Arc` (not the tensors) when it changed.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::{Arc, RwLock};

use super::Tensors;

pub type VersionedParams = Arc<Tensors>;

/// Shared parameter store for one policy.
pub struct ParamStore {
    version: AtomicU32,
    params: RwLock<VersionedParams>,
}

impl ParamStore {
    pub fn new(initial: VersionedParams) -> Arc<Self> {
        Arc::new(ParamStore {
            version: AtomicU32::new(1),
            params: RwLock::new(initial),
        })
    }

    /// Current version (monotonically increasing from 1).
    #[inline]
    pub fn version(&self) -> u32 {
        self.version.load(Ordering::Acquire)
    }

    /// Publish new parameters; returns the new version.
    pub fn publish(&self, params: VersionedParams) -> u32 {
        {
            let mut guard = self.params.write().unwrap();
            *guard = params;
        }
        // Bump after the swap so a reader that sees the new version also
        // sees the new params.
        self.version.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Fetch the current parameters (cheap Arc clone).
    pub fn fetch(&self) -> (u32, VersionedParams) {
        // Read version first: if a publish races us we may return the newer
        // params with the older version number, which only *overestimates*
        // policy lag — safe for accounting.
        let v = self.version();
        let p = self.params.read().unwrap().clone();
        (v, p)
    }

    /// Fetch only if newer than `have`.
    pub fn fetch_if_newer(&self, have: u32) -> Option<(u32, VersionedParams)> {
        if self.version() > have {
            Some(self.fetch())
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{lit_f32, Tensors};

    fn params(v: f32) -> VersionedParams {
        Arc::new(Tensors(vec![lit_f32(&[2], &[v, v]).unwrap()]))
    }

    #[test]
    fn publish_bumps_version() {
        let store = ParamStore::new(params(0.0));
        assert_eq!(store.version(), 1);
        assert_eq!(store.publish(params(1.0)), 2);
        assert_eq!(store.version(), 2);
    }

    #[test]
    fn fetch_if_newer_behaviour() {
        let store = ParamStore::new(params(0.0));
        let (v, _) = store.fetch();
        assert_eq!(v, 1);
        assert!(store.fetch_if_newer(1).is_none());
        store.publish(params(2.0));
        let (v2, p2) = store.fetch_if_newer(1).unwrap();
        assert_eq!(v2, 2);
        assert_eq!(p2[0].to_vec::<f32>().unwrap(), vec![2.0, 2.0]);
    }

    #[test]
    fn concurrent_publish_fetch_is_consistent() {
        let store = ParamStore::new(params(0.0));
        let s2 = store.clone();
        let writer = std::thread::spawn(move || {
            for i in 1..200 {
                s2.publish(params(i as f32));
            }
        });
        let mut last_v = 0;
        for _ in 0..500 {
            let (v, p) = store.fetch();
            assert!(v >= last_v, "version went backwards");
            last_v = v;
            let vals = p[0].to_vec::<f32>().unwrap();
            assert_eq!(vals[0], vals[1], "torn read");
        }
        writer.join().unwrap();
        assert_eq!(store.version(), 200);
    }
}
