//! Literal construction/extraction helpers used on the hot paths.
//!
//! Program inputs are host [`Literal`]s; these helpers build them from plain
//! slices (one copy into the literal's owned storage) and read results back
//! into reusable Vecs.  They are backend-agnostic — see [`super::literal`].

use anyhow::{anyhow, Result};

use super::literal::Literal;

/// f32 literal with the given dims (row-major).
pub fn lit_f32(dims: &[usize], data: &[f32]) -> Result<Literal> {
    Literal::f32(dims, data.to_vec()).map_err(|e| anyhow!("lit_f32: {e:#}"))
}

/// u8 literal (pixel observations).
pub fn lit_u8(dims: &[usize], data: &[u8]) -> Result<Literal> {
    Literal::u8(dims, data.to_vec()).map_err(|e| anyhow!("lit_u8: {e:#}"))
}

/// i32 literal (action indices).
pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    Literal::i32(dims, data.to_vec()).map_err(|e| anyhow!("lit_i32: {e:#}"))
}

/// u32 scalar (seeds).
pub fn lit_u32_scalar(v: u32) -> Literal {
    Literal::u32_scalar(v)
}

/// Copy a literal's f32 contents into a Vec.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_f32_vec: {e:#}"))
}

/// Copy a literal's f32 contents into an existing buffer (no allocation).
pub fn read_f32_into(lit: &Literal, out: &mut [f32]) -> Result<()> {
    lit.copy_raw_to::<f32>(out)
        .map_err(|e| anyhow!("read_f32_into: {e:#}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let lit = lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn f32_scalar() {
        let lit = lit_f32(&[], &[7.5]).unwrap();
        assert_eq!(lit.element_count(), 1);
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![7.5]);
    }

    #[test]
    fn wrong_length_rejected() {
        assert!(lit_f32(&[2, 2], &[1.0]).is_err());
    }

    #[test]
    fn u8_and_i32() {
        let l = lit_u8(&[4], &[1, 2, 3, 255]).unwrap();
        assert_eq!(l.element_count(), 4);
        let l = lit_i32(&[2], &[-5, 9]).unwrap();
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![-5, 9]);
    }

    #[test]
    fn read_into_no_alloc() {
        let lit = lit_f32(&[3], &[9.0, 8.0, 7.0]).unwrap();
        let mut buf = [0f32; 3];
        read_f32_into(&lit, &mut buf).unwrap();
        assert_eq!(buf, [9.0, 8.0, 7.0]);
    }

    #[test]
    fn seed_scalar_is_u32() {
        let lit = lit_u32_scalar(42);
        assert_eq!(lit.as_u32().unwrap(), &[42]);
    }
}
