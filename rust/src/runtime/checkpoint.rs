//! Checkpointing: parameter snapshots on disk.
//!
//! Format (little-endian, version-tagged):
//!
//! ```text
//! magic "SFCKPT01" | u32 n_tensors |
//!   per tensor: u32 name_len | name bytes | u32 ndims | u64 dims... |
//!               u64 data_len_bytes | f32 data...
//! ```
//!
//! Checkpoints are validated against the live manifest on load, so a
//! checkpoint from a different spec (or a stale artifacts dir) fails fast
//! with a descriptive error instead of feeding mis-shaped tensors to PJRT.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::{lit_f32, Manifest, Tensors};

const MAGIC: &[u8; 8] = b"SFCKPT01";

/// Save a parameter set, creating parent directories.
pub fn save(path: &Path, manifest: &Manifest, params: &Tensors) -> Result<()> {
    if params.len() != manifest.n_params {
        return Err(anyhow!(
            "cannot save: {} tensors vs manifest {}",
            params.len(),
            manifest.n_params
        ));
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension("tmp");
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        f.write_all(&(params.len() as u32).to_le_bytes())?;
        for (def, lit) in manifest.params.iter().zip(params.iter()) {
            let data = lit
                .to_vec::<f32>()
                .map_err(|e| anyhow!("read {}: {e:?}", def.name))?;
            f.write_all(&(def.name.len() as u32).to_le_bytes())?;
            f.write_all(def.name.as_bytes())?;
            f.write_all(&(def.shape.len() as u32).to_le_bytes())?;
            for &d in &def.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&((data.len() * 4) as u64).to_le_bytes())?;
            for x in &data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
    }
    // Atomic-ish publish.
    std::fs::rename(&tmp, path)?;
    Ok(())
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Load a checkpoint, validating names and shapes against `manifest`.
pub fn load(path: &Path, manifest: &Manifest) -> Result<Tensors> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(anyhow!("{path:?}: not a sample-factory checkpoint"));
    }
    let n = read_u32(&mut f)? as usize;
    if n != manifest.n_params {
        return Err(anyhow!(
            "{path:?}: {n} tensors but spec '{}' expects {} — wrong spec?",
            manifest.name,
            manifest.n_params
        ));
    }
    let mut out = Vec::with_capacity(n);
    for def in &manifest.params {
        let name_len = read_u32(&mut f)? as usize;
        if name_len > 4096 {
            return Err(anyhow!("{path:?}: corrupt name length"));
        }
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|_| anyhow!("corrupt name"))?;
        if name != def.name {
            return Err(anyhow!(
                "{path:?}: tensor '{name}' where '{}' expected — checkpoint \
                 from a different spec/ordering",
                def.name
            ));
        }
        let ndims = read_u32(&mut f)? as usize;
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(read_u64(&mut f)? as usize);
        }
        if dims != def.shape {
            return Err(anyhow!(
                "{path:?}: '{name}' shape {dims:?} != manifest {:?}",
                def.shape
            ));
        }
        let byte_len = read_u64(&mut f)? as usize;
        let expect: usize = def.shape.iter().product::<usize>().max(1) * 4;
        if byte_len != expect {
            return Err(anyhow!("{path:?}: '{name}' has {byte_len} bytes, want {expect}"));
        }
        let mut bytes = vec![0u8; byte_len];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        out.push(lit_f32(&def.shape, &data)?);
    }
    Ok(Tensors(out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::to_f32_vec;

    fn manifest() -> Manifest {
        Manifest::parse(
            r#"{"name":"t","obs_shape":[8,8,3],"action_heads":[3],
                "hidden":4,"policy_batch":2,"train_batch":2,"rollout":4,
                "params":[{"name":"a/w","shape":[2,3],"dtype":"f32"},
                           {"name":"a/b","shape":[3],"dtype":"f32"}],
                "n_params":2,
                "hyper_names":["lr"],"hypers_default":[0.001],
                "metric_names":["loss"]}"#,
        )
        .unwrap()
    }

    fn params() -> Tensors {
        Tensors(vec![
            lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap(),
            lit_f32(&[3], &[-1.0, 0.5, 9.0]).unwrap(),
        ])
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("sf_ckpt_test");
        let path = dir.join("p.ckpt");
        let man = manifest();
        save(&path, &man, &params()).unwrap();
        let loaded = load(&path, &man).unwrap();
        assert_eq!(
            to_f32_vec(&loaded[0]).unwrap(),
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]
        );
        assert_eq!(to_f32_vec(&loaded[1]).unwrap(), vec![-1.0, 0.5, 9.0]);
    }

    #[test]
    fn rejects_wrong_manifest() {
        let dir = std::env::temp_dir().join("sf_ckpt_test2");
        let path = dir.join("p.ckpt");
        let man = manifest();
        save(&path, &man, &params()).unwrap();
        let mut other = manifest();
        other.params[0].shape = vec![3, 2];
        let err = load(&path, &other).unwrap_err().to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join("sf_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(load(&path, &manifest()).is_err());
    }
}
