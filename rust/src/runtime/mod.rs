//! The model runtime: a backend-abstracted executor for the three programs
//! every model spec provides (`init`, `policy`, `train`).
//!
//! Two interchangeable [`Backend`] implementations sit behind the same
//! [`Literal`]-in / [`Literal`]-out [`Program`] interface:
//!
//! * [`native`] (cargo feature `native`, default) — a pure-Rust execution
//!   engine: conv-GRU forward, multi-discrete heads, and the full
//!   APPO/V-trace train step with analytic gradients on f32 slices.  No
//!   Python, no XLA, no artifacts directory — `ModelPrograms::load`
//!   synthesizes the model from the built-in spec table, so a clean
//!   checkout tests green (the EnvPool-style "self-contained engine"
//!   argument; Weng et al., 2022).
//! * [`pjrt`] (cargo feature `pjrt`) — the original AOT path: HLO text
//!   lowered by `python/compile` (`make artifacts`) compiled and executed
//!   through the PJRT C API via the `xla` crate.
//!
//! Shared infrastructure:
//!
//! * [`manifest`] — the model contract (shapes/ordering); parsed from
//!   `artifacts/<spec>/manifest.json` on the PJRT path, synthesized by the
//!   native backend.
//! * [`params::ParamStore`] — the versioned published parameters: the
//!   learner publishes, policy workers fetch on version change.  This is
//!   the in-process analogue of the paper's "model in shared CUDA memory,
//!   update <1 ms" (§3.4): publishing swaps an `Arc`, fetching clones it.

pub mod checkpoint;
pub mod literal;
pub mod literals;
pub mod manifest;
pub mod params;
pub mod placement;

#[cfg(feature = "native")]
pub mod native;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(any(feature = "native", feature = "pjrt")))]
compile_error!(
    "enable at least one runtime backend feature: `native` (default) or `pjrt`"
);

pub use literal::{DType, Literal};
pub use literals::{lit_f32, lit_i32, lit_u32_scalar, lit_u8, read_f32_into, to_f32_vec};
pub use manifest::Manifest;
pub use params::{ParamStore, VersionedParams};

use anyhow::{anyhow, Context, Result};
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// A batch of host tensors that can cross thread boundaries.  Plain owned
/// memory — `Send + Sync` for free (the PJRT backend converts at its own
/// boundary instead of leaking FFI handles into the coordinator).
#[derive(Clone)]
pub struct Tensors(pub Vec<Literal>);

impl Deref for Tensors {
    type Target = Vec<Literal>;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

impl DerefMut for Tensors {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.0
    }
}

impl std::fmt::Debug for Tensors {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensors({} literals)", self.0.len())
    }
}

/// Opaque backend-resident input cache returned by [`Executable::upload`]:
/// device buffers on PJRT, a host-side snapshot on the native backend.
pub struct DeviceBuffers(Box<dyn std::any::Any + Send + Sync>);

impl DeviceBuffers {
    pub fn new<T: Send + Sync + 'static>(inner: T) -> DeviceBuffers {
        DeviceBuffers(Box::new(inner))
    }

    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.0.downcast_ref::<T>()
    }
}

/// The native backend's cache representation (also the default for any
/// backend that has no device memory): cloned input literals.
pub struct HostCache(pub Vec<Literal>);

/// One executable program: host literals in, host literals out.
pub trait Program: Send + Sync {
    fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>>;

    /// Cache a (typically parameter) input prefix backend-side; see
    /// [`Program::run_cached`].  Default: host snapshot.
    fn upload(&self, inputs: &[&Literal]) -> Result<DeviceBuffers> {
        Ok(DeviceBuffers::new(HostCache(
            inputs.iter().map(|l| (*l).clone()).collect(),
        )))
    }

    /// Execute with a cached input prefix plus fresh inputs.  §Perf on the
    /// PJRT backend: parameters dominate the input bytes of the policy
    /// program; caching their upload cuts per-batch host->device traffic to
    /// just the observation/hidden tensors.  The native backend reads host
    /// memory either way — the default impl just re-assembles the list.
    fn run_cached(&self, cached: &DeviceBuffers, fresh: &[&Literal]) -> Result<Vec<Literal>> {
        let host = cached
            .downcast_ref::<HostCache>()
            .ok_or_else(|| anyhow!("input cache was created by a different backend"))?;
        let mut refs: Vec<&Literal> = Vec::with_capacity(host.0.len() + fresh.len());
        refs.extend(host.0.iter());
        refs.extend_from_slice(fresh);
        self.run(&refs)
    }
}

/// A runtime backend: turns a (spec, artifacts dir) into the three
/// executable programs plus the manifest describing their contract.
pub trait Backend: Send + Sync {
    fn platform(&self) -> String;
    fn load_model(&self, artifacts_dir: &str, spec: &str) -> Result<LoadedModel>;

    /// Like [`Backend::load_model`], but with a reduced-precision
    /// **inference** dtype (`--inference_dtype f16|i8`) for the policy
    /// program's serving hot path.  Training is always f32.  Backends
    /// without a quantized path (PJRT) keep this default, which rejects
    /// anything but f32 instead of silently serving full precision.
    fn load_model_with(
        &self,
        artifacts_dir: &str,
        spec: &str,
        dtype: crate::config::InferenceDtype,
    ) -> Result<LoadedModel> {
        if dtype != crate::config::InferenceDtype::F32 {
            return Err(anyhow!(
                "backend '{}' supports only --inference_dtype f32",
                self.platform()
            ));
        }
        self.load_model(artifacts_dir, spec)
    }
}

/// What [`Backend::load_model`] produces.
pub struct LoadedModel {
    pub manifest: Manifest,
    pub init: Executable,
    pub policy: Executable,
    pub train: Executable,
}

/// A compiled/loaded program with a display name for error messages.
pub struct Executable {
    prog: Box<dyn Program>,
    name: String,
}

impl Executable {
    pub fn new(name: impl Into<String>, prog: Box<dyn Program>) -> Executable {
        Executable { prog, name: name.into() }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with host literals, returning the decomposed outputs.
    pub fn run(&self, inputs: &[&Literal]) -> Result<Vec<Literal>> {
        self.prog
            .run(inputs)
            .with_context(|| format!("executing {}", self.name))
    }

    /// Cache an input prefix backend-side (typically parameters, refreshed
    /// only when the learner publishes).
    pub fn upload(&self, inputs: &[&Literal]) -> Result<DeviceBuffers> {
        self.prog
            .upload(inputs)
            .with_context(|| format!("uploading inputs of {}", self.name))
    }

    /// Execute with a cached input prefix plus fresh host literals.
    pub fn run_cached(
        &self,
        cached: &DeviceBuffers,
        fresh: &[&Literal],
    ) -> Result<Vec<Literal>> {
        self.prog
            .run_cached(cached, fresh)
            .with_context(|| format!("executing {}", self.name))
    }
}

/// The active backend behind a uniform handle.
pub struct Runtime {
    backend: Arc<dyn Backend>,
}

impl Runtime {
    /// The default CPU runtime.  Picks the `native` backend when compiled
    /// in (the default feature set); `SF_BACKEND=pjrt|native` overrides
    /// when both backends are available.
    pub fn cpu() -> Result<Runtime> {
        match std::env::var("SF_BACKEND").unwrap_or_default().as_str() {
            "" => Self::default_backend(),
            "native" => Self::native(),
            "pjrt" => Self::pjrt(),
            other => Err(anyhow!(
                "unknown SF_BACKEND '{other}' (expected 'native' or 'pjrt')"
            )),
        }
    }

    // The cfg-paired `return` statements below keep exactly one arm per
    // feature combination; clippy's needless_return doesn't understand the
    // pattern.
    #[allow(clippy::needless_return)]
    fn default_backend() -> Result<Runtime> {
        #[cfg(feature = "native")]
        return Self::native();
        #[cfg(not(feature = "native"))]
        return Self::pjrt();
    }

    /// The pure-Rust backend (requires the `native` feature).
    #[allow(clippy::needless_return)]
    pub fn native() -> Result<Runtime> {
        #[cfg(feature = "native")]
        return Ok(Runtime { backend: Arc::new(native::NativeBackend) });
        #[cfg(not(feature = "native"))]
        return Err(anyhow!(
            "this build does not include the `native` backend (rebuild with \
             --features native)"
        ));
    }

    /// The PJRT/XLA backend (requires the `pjrt` feature + artifacts).
    #[allow(clippy::needless_return)]
    pub fn pjrt() -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        return Ok(Runtime { backend: Arc::new(pjrt::PjrtBackend::cpu()?) });
        #[cfg(not(feature = "pjrt"))]
        return Err(anyhow!(
            "this build does not include the `pjrt` backend (rebuild with \
             --features pjrt)"
        ));
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }
}

/// The three programs for one model spec + its manifest.
pub struct ModelPrograms {
    pub manifest: Manifest,
    pub init: Executable,
    pub policy: Executable,
    pub train: Executable,
}

impl ModelPrograms {
    /// Load everything for `spec`.  On the native backend this synthesizes
    /// the model from the built-in spec table (no `make artifacts` needed);
    /// on PJRT it parses `artifacts_dir/<spec>/` and compiles the HLO.
    pub fn load(rt: &Runtime, artifacts_dir: &str, spec: &str) -> Result<Self> {
        Self::load_with(rt, artifacts_dir, spec, crate::config::InferenceDtype::F32)
    }

    /// [`ModelPrograms::load`] with an explicit inference dtype for the
    /// policy program (`--inference_dtype`).  f16/i8 affect only the
    /// serving path (`policy.upload` + `policy.run_cached`); `init` and
    /// `train` stay f32 and bit-identical.
    pub fn load_with(
        rt: &Runtime,
        artifacts_dir: &str,
        spec: &str,
        dtype: crate::config::InferenceDtype,
    ) -> Result<Self> {
        let LoadedModel { manifest, init, policy, train } = rt
            .backend
            .load_model_with(artifacts_dir, spec, dtype)
            .with_context(|| format!("loading model for spec '{spec}'"))?;
        Ok(ModelPrograms { manifest, init, policy, train })
    }

    /// Run the init program: seed -> fresh parameters.
    pub fn init_params(&self, seed: u32) -> Result<Tensors> {
        let seed_lit = lit_u32_scalar(seed);
        let out = self.init.run(&[&seed_lit])?;
        if out.len() != self.manifest.n_params {
            return Err(anyhow!(
                "init returned {} tensors, manifest says {}",
                out.len(),
                self.manifest.n_params
            ));
        }
        Ok(Tensors(out))
    }

    /// Fresh Adam state: zeroed m and v plus a zero step counter.
    pub fn zero_opt_state(&self) -> Result<(Tensors, Tensors, Tensors)> {
        let mut m = Vec::with_capacity(self.manifest.n_params);
        let mut v = Vec::with_capacity(self.manifest.n_params);
        for p in &self.manifest.params {
            let n: usize = p.shape.iter().product::<usize>().max(1);
            let zeros = vec![0f32; n];
            m.push(lit_f32(&p.shape, &zeros)?);
            v.push(lit_f32(&p.shape, &zeros)?);
        }
        let step = Tensors(vec![lit_f32(&[], &[0.0])?]);
        Ok((Tensors(m), Tensors(v), step))
    }
}

/// A fully initialised learner state (params + Adam state), owned by the
/// learner thread and chained through consecutive train_step executions.
pub struct LearnerState {
    pub params: Tensors,
    pub m: Tensors,
    pub v: Tensors,
    /// Single-element tensor: the Adam step counter.
    pub step: Tensors,
}

impl LearnerState {
    pub fn fresh(progs: &ModelPrograms, seed: u32) -> Result<Self> {
        let params = progs.init_params(seed)?;
        let (m, v, step) = progs.zero_opt_state()?;
        Ok(LearnerState { params, m, v, step })
    }

    pub fn publish(&self) -> VersionedParams {
        Arc::new(self.params.clone())
    }
}
