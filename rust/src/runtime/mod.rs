//! PJRT runtime: loads the AOT artifacts (HLO text + manifest) and executes
//! them from the Rust hot path.  This is the only place the `xla` crate is
//! touched; Python never runs after `make artifacts`.
//!
//! * [`manifest`] — the AOT-time contract (shapes/ordering) parsed from
//!   `artifacts/<spec>/manifest.json`.
//! * [`Runtime`] — a PJRT CPU client; compiles HLO text into executables.
//! * [`ModelPrograms`] — the three programs (`init`, `policy`, `train`)
//!   for one model spec.
//! * [`params::ParamStore`] — the versioned published parameters: the
//!   learner publishes, policy workers fetch on version change.  This is
//!   the in-process analogue of the paper's "model in shared CUDA memory,
//!   update <1 ms" (§3.4): publishing swaps an `Arc`, fetching clones it.

pub mod checkpoint;
pub mod literals;
pub mod manifest;
pub mod params;

pub use literals::{lit_f32, lit_i32, lit_u32_scalar, lit_u8, read_f32_into, to_f32_vec};
pub use manifest::Manifest;
pub use params::{ParamStore, VersionedParams};

use anyhow::{anyhow, Context, Result};
use std::ops::{Deref, DerefMut};
use std::path::Path;
use std::sync::Arc;

/// A batch of host tensors that can cross thread boundaries.
///
/// SAFETY: `xla::Literal` owns plain host memory (an `xla::Literal` on the
/// C++ side) with no thread affinity; every API we use through `&self`
/// (`to_vec`, `copy_raw_to`, `shape`, execute inputs) is read-only, and
/// mutation (`copy_raw_from`) requires `&mut self`.  The raw pointer inside
/// the crate's wrapper is the only reason it isn't auto-`Send`/`Sync`.
pub struct Tensors(pub Vec<xla::Literal>);

unsafe impl Send for Tensors {}
unsafe impl Sync for Tensors {}

impl Deref for Tensors {
    type Target = Vec<xla::Literal>;
    fn deref(&self) -> &Self::Target {
        &self.0
    }
}

impl DerefMut for Tensors {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.0
    }
}

impl Clone for Tensors {
    fn clone(&self) -> Self {
        Tensors(self.0.clone())
    }
}

impl std::fmt::Debug for Tensors {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensors({} literals)", self.0.len())
    }
}

/// A PJRT client plus compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    /// Create the CPU PJRT client (the container has no accelerator).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e:?}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load HLO text and compile it.  HLO *text* is the interchange format
    /// (jax >= 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects;
    /// the text parser reassigns ids — see DESIGN.md / aot.py).
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        Ok(Executable {
            exe,
            client: self.client.clone(),
            name: path.display().to_string(),
        })
    }
}

/// A compiled program.  All our programs are lowered with
/// `return_tuple=True`, so execution returns one tuple literal that we
/// decompose into the per-output literals.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    name: String,
}

// SAFETY: PJRT loaded executables are documented thread-safe for Execute;
// we only call `execute` through `&self`.  The client handle inside is
// reference-counted on the C++ side.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

// SAFETY: the PJRT CPU client is thread-safe (it backs multi-threaded
// jax/TF runtimes); we only compile through `&self`.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Executable {
    /// Execute with host literals, returning the decomposed outputs.
    ///
    /// NOTE: this deliberately avoids `PjRtLoadedExecutable::execute`
    /// (literal inputs): the crate's C++ shim uploads each input literal to
    /// a device buffer it `release()`s and never frees — a per-call leak of
    /// the whole input set (~hundreds of MB/min at our call rates).  We
    /// upload through `buffer_from_host_literal` so Rust owns the buffers
    /// (freed on drop) and dispatch via `execute_b`.
    pub fn run(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut bufs: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (i, l) in inputs.iter().enumerate() {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("upload input {i} of {}: {e:?}", self.name))?,
            );
        }
        self.run_b(&bufs)
    }

    /// Execute with device-resident buffers (no host->device copies); used
    /// by callers that cache e.g. parameter uploads across calls.
    pub fn run_b(&self, inputs: &[xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&inputs.iter().collect::<Vec<_>>())
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let mut lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch outputs of {}: {e:?}", self.name))?;
        lit.decompose_tuple()
            .map_err(|e| anyhow!("untuple outputs of {}: {e:?}", self.name))
    }

    /// Execute with a cached device-buffer prefix (typically parameters,
    /// re-uploaded only when the learner publishes) plus fresh host-literal
    /// inputs.  §Perf: parameters dominate the input bytes of the policy
    /// program; caching their upload cuts per-batch host->device traffic to
    /// just the observation/hidden tensors.
    pub fn run_cached(
        &self,
        cached: &[xla::PjRtBuffer],
        fresh: &[&xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let fresh_bufs = self.upload(fresh)?;
        let mut refs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(cached.len() + fresh_bufs.len());
        refs.extend(cached.iter());
        refs.extend(fresh_bufs.iter());
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&refs)
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        let mut lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch outputs of {}: {e:?}", self.name))?;
        lit.decompose_tuple()
            .map_err(|e| anyhow!("untuple outputs of {}: {e:?}", self.name))
    }

    /// Number of raw output buffers one execution produces (diagnostic:
    /// tells whether this PJRT build untuples results).
    pub fn probe_output_buffers(&self, inputs: &[&xla::Literal]) -> Result<usize> {
        let bufs = self.upload(inputs)?;
        let outs = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(&bufs.iter().collect::<Vec<_>>())
            .map_err(|e| anyhow!("execute {}: {e:?}", self.name))?;
        Ok(outs[0].len())
    }

    /// Upload a set of host literals to device buffers (for `run_b`).
    pub fn upload(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = Vec::with_capacity(inputs.len());
        for (i, l) in inputs.iter().enumerate() {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("upload {i} of {}: {e:?}", self.name))?,
            );
        }
        Ok(bufs)
    }
}

/// The three compiled programs for one model spec + its manifest.
pub struct ModelPrograms {
    pub manifest: Manifest,
    pub init: Executable,
    pub policy: Executable,
    pub train: Executable,
}

impl ModelPrograms {
    /// Load and compile everything for `spec` from `artifacts_dir`.
    pub fn load(rt: &Runtime, artifacts_dir: &str, spec: &str) -> Result<Self> {
        let dir = Path::new(artifacts_dir).join(spec);
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest for spec '{spec}'"))?;
        let init = rt.load_hlo_text(&dir.join("init.hlo.txt"))?;
        let policy = rt.load_hlo_text(&dir.join("policy.hlo.txt"))?;
        let train = rt.load_hlo_text(&dir.join("train.hlo.txt"))?;
        Ok(ModelPrograms { manifest, init, policy, train })
    }

    /// Run the init program: seed -> fresh parameters.
    pub fn init_params(&self, seed: u32) -> Result<Tensors> {
        let seed_lit = lit_u32_scalar(seed);
        let out = self.init.run(&[&seed_lit])?;
        if out.len() != self.manifest.n_params {
            return Err(anyhow!(
                "init returned {} tensors, manifest says {}",
                out.len(),
                self.manifest.n_params
            ));
        }
        Ok(Tensors(out))
    }

    /// Fresh Adam state: zeroed m and v plus a zero step counter.
    pub fn zero_opt_state(&self) -> Result<(Tensors, Tensors, Tensors)> {
        let mut m = Vec::with_capacity(self.manifest.n_params);
        let mut v = Vec::with_capacity(self.manifest.n_params);
        for p in &self.manifest.params {
            let n: usize = p.shape.iter().product::<usize>().max(1);
            let zeros = vec![0f32; n];
            m.push(lit_f32(&p.shape, &zeros)?);
            v.push(lit_f32(&p.shape, &zeros)?);
        }
        let step = Tensors(vec![lit_f32(&[], &[0.0])?]);
        Ok((Tensors(m), Tensors(v), step))
    }
}

/// A fully initialised learner state (params + Adam state), owned by the
/// learner thread and chained through consecutive train_step executions.
pub struct LearnerState {
    pub params: Tensors,
    pub m: Tensors,
    pub v: Tensors,
    /// Single-element tensor: the Adam step counter.
    pub step: Tensors,
}

impl LearnerState {
    pub fn fresh(progs: &ModelPrograms, seed: u32) -> Result<Self> {
        let params = progs.init_params(seed)?;
        let (m, v, step) = progs.zero_opt_state()?;
        Ok(LearnerState { params, m, v, step })
    }

    pub fn publish(&self) -> VersionedParams {
        Arc::new(self.params.clone())
    }
}
