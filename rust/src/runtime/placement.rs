//! Affinity-aware thread placement (`--cpu_affinity true`).
//!
//! The paper's large-scale recipe ships `--set_workers_cpu_affinity=True`,
//! and the architectural study of RL training systems (Inci et al., 2020)
//! shows that past ~16 workers core placement — not algorithm work —
//! decides throughput.  This module is the whole placement story:
//!
//! * **Topology discovery** — parse `/sys/devices/system/cpu` on Linux
//!   (online list + per-cpu `core_id`/`physical_package_id`); everywhere
//!   else fall back to "every logical CPU is its own core" so the plan
//!   degrades to a no-op spread instead of failing.
//! * **Plan computation** — a [`PlacementPlan`]: the first
//!   `reserved_cores` physical cores (all their SMT siblings) are the
//!   *reserved set* for the policy workers, learner + assembly stages and
//!   the native pool; rollout workers are spread round-robin across the
//!   remaining physical cores, same-package-as-reserved first, so each
//!   `ShardedQueue` SPSC shard's producer (the rollout worker) and its
//!   consumer-side drain (the policy worker / learner assembly on the
//!   reserved set) stay in one cache domain while capacity allows.
//! * **Application** — a libc-free `sched_setaffinity` raw-syscall
//!   wrapper ([`pin_current_thread`]); on non-Linux (or unsupported
//!   arch) pinning is a graceful no-op and the run proceeds unpinned.
//!
//! `SF_PIN_CPUS=0-3,8` restricts the CPU universe the plan draws from
//! (e.g. to keep a box half-free).  An unparsable value is a **hard
//! startup error** — silent misconfiguration is how throughput
//! experiments lie.

use std::sync::OnceLock;

/// One logical CPU with its physical location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CpuInfo {
    /// Logical CPU index (the bit set in the affinity mask).
    pub cpu: usize,
    /// Physical core id within the package (SMT siblings share it).
    pub core: usize,
    /// Package / socket id (the cache-domain boundary we care about).
    pub package: usize,
}

/// The machine's CPU layout as far as placement cares.
#[derive(Clone, Debug)]
pub struct Topology {
    pub cpus: Vec<CpuInfo>,
}

impl Topology {
    /// Discover the topology.  Never fails: on non-Linux, or when sysfs
    /// is unreadable, every logical CPU counts as its own physical core
    /// on package 0 (pinning still spreads threads, just without SMT or
    /// package awareness).
    pub fn detect() -> Topology {
        #[cfg(target_os = "linux")]
        if let Some(cpus) = detect_linux() {
            return Topology { cpus };
        }
        Topology::flat(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        )
    }

    /// A synthetic flat topology: `n` CPUs, each its own core, one package.
    pub fn flat(n: usize) -> Topology {
        Topology {
            cpus: (0..n.max(1))
                .map(|c| CpuInfo { cpu: c, core: c, package: 0 })
                .collect(),
        }
    }
}

#[cfg(target_os = "linux")]
fn detect_linux() -> Option<Vec<CpuInfo>> {
    let online = std::fs::read_to_string("/sys/devices/system/cpu/online").ok()?;
    let cpus = parse_cpu_list(online.trim()).ok()?;
    let mut out = Vec::with_capacity(cpus.len());
    for c in cpus {
        let base = format!("/sys/devices/system/cpu/cpu{c}/topology");
        // Missing topology files (containers often hide them): treat the
        // CPU as its own core — degraded but usable.
        let core = read_sys_usize(&format!("{base}/core_id")).unwrap_or(c);
        let package =
            read_sys_usize(&format!("{base}/physical_package_id")).unwrap_or(0);
        out.push(CpuInfo { cpu: c, core, package });
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

#[cfg(target_os = "linux")]
fn read_sys_usize(path: &str) -> Option<usize> {
    std::fs::read_to_string(path).ok()?.trim().parse().ok()
}

/// Parse a kernel-style CPU list: `"0-3,8,10-11"`.  Used for both the
/// sysfs `online` file and the `SF_PIN_CPUS` override.
pub fn parse_cpu_list(s: &str) -> Result<Vec<usize>, String> {
    let bad = |tok: &str| {
        format!(
            "invalid CPU list '{s}': bad token '{tok}' (expected e.g. '0-3,8')"
        )
    };
    let mut out = Vec::new();
    for tok in s.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            return Err(bad(tok));
        }
        if let Some((lo, hi)) = tok.split_once('-') {
            let lo: usize = lo.trim().parse().map_err(|_| bad(tok))?;
            let hi: usize = hi.trim().parse().map_err(|_| bad(tok))?;
            if hi < lo {
                return Err(format!(
                    "invalid CPU list '{s}': descending range '{tok}'"
                ));
            }
            out.extend(lo..=hi);
        } else {
            out.push(tok.parse().map_err(|_| bad(tok))?);
        }
    }
    out.sort_unstable();
    out.dedup();
    Ok(out)
}

/// Where every thread of a training run should live.  Computed once in
/// `run_appo` and shared through `SharedCtx`; all the `pin_*` methods are
/// no-ops when the plan is disabled, so call sites stay unconditional.
#[derive(Debug)]
pub struct PlacementPlan {
    enabled: bool,
    /// CPU set (one physical core + SMT siblings) per rollout worker.
    rollout: Vec<Vec<usize>>,
    /// CPU set shared by policy workers, learner/assembly and the pool.
    reserved: Vec<usize>,
}

impl PlacementPlan {
    /// A plan that pins nothing (affinity off — the default).
    pub fn disabled() -> PlacementPlan {
        PlacementPlan { enabled: false, rollout: Vec::new(), reserved: Vec::new() }
    }

    /// Compute the plan for this machine.  `SF_PIN_CPUS` (if set)
    /// restricts the universe; an invalid value is a hard error even when
    /// affinity is off, so a typo never silently reverts to "pin
    /// everywhere".
    pub fn compute(
        enabled: bool,
        reserved_cores: usize,
        num_workers: usize,
    ) -> Result<PlacementPlan, String> {
        let pin_override = match std::env::var("SF_PIN_CPUS") {
            Ok(s) => Some(parse_cpu_list(s.trim()).map_err(|e| {
                format!("SF_PIN_CPUS is set but unusable: {e}")
            })?),
            Err(_) => None,
        };
        if !enabled {
            return Ok(PlacementPlan::disabled());
        }
        Ok(PlacementPlan::from_parts(
            &Topology::detect(),
            pin_override.as_deref(),
            reserved_cores,
            num_workers,
        ))
    }

    /// Pure plan construction (unit-testable with synthetic topologies).
    pub fn from_parts(
        topo: &Topology,
        pin_override: Option<&[usize]>,
        reserved_cores: usize,
        num_workers: usize,
    ) -> PlacementPlan {
        // Universe: the override list intersected with known CPUs, or
        // everything the topology reports.
        let universe: Vec<CpuInfo> = match pin_override {
            Some(list) => topo
                .cpus
                .iter()
                .filter(|c| list.contains(&c.cpu))
                .copied()
                .collect(),
            None => topo.cpus.clone(),
        };
        if universe.is_empty() {
            return PlacementPlan::disabled();
        }

        // Group logical CPUs into physical cores, ordered (package, core).
        let mut cores: Vec<((usize, usize), Vec<usize>)> = Vec::new();
        for c in &universe {
            let key = (c.package, c.core);
            match cores.iter_mut().find(|(k, _)| *k == key) {
                Some((_, v)) => v.push(c.cpu),
                None => cores.push((key, vec![c.cpu])),
            }
        }
        cores.sort();

        // Reserved set: the first `reserved_cores` cores — but always
        // leave at least one core for the rollout workers when possible.
        let n_res = reserved_cores.max(1).min(cores.len().saturating_sub(1)).max(
            if cores.len() == 1 { 1 } else { 0 },
        );
        let reserved: Vec<usize> =
            cores[..n_res].iter().flat_map(|(_, v)| v.iter().copied()).collect();
        let mut rest: Vec<&((usize, usize), Vec<usize>)> =
            cores[n_res..].iter().collect();
        if rest.is_empty() {
            // One core total: everything shares it; pinning is then only
            // an isolation statement, not a spread.
            rest = cores.iter().collect();
        }
        // Same-package-as-reserved cores first: a rollout worker's SPSC
        // shard is drained by a reserved-set thread, so filling the
        // reserved package first keeps producer and consumer in one
        // cache domain while there is room.
        let res_pkg = cores[0].0 .0;
        rest.sort_by_key(|((pkg, core), _)| (*pkg != res_pkg, *pkg, *core));

        let rollout = (0..num_workers)
            .map(|w| rest[w % rest.len()].1.clone())
            .collect();
        PlacementPlan { enabled: true, rollout, reserved }
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Pin the calling thread to rollout worker `w`'s core.
    pub fn pin_rollout(&self, w: usize) {
        if self.enabled {
            pin_current_thread(&self.rollout[w % self.rollout.len()]);
        }
    }

    /// Pin the calling thread to the reserved set (policy workers,
    /// learner train + assembly stages, monitor).
    pub fn pin_reserved(&self) {
        if self.enabled {
            pin_current_thread(&self.reserved);
        }
    }

    /// Record the reserved set as the native pool's home: pool workers
    /// spawned *after* this call pin themselves there.  Call before the
    /// first pool use of the process (the pool is a lazy global).
    pub fn install_pool_hint(&self) {
        if self.enabled && !self.reserved.is_empty() {
            let _ = POOL_CPUS.set(self.reserved.clone());
        }
    }

    /// One-line human description for the startup log.
    pub fn describe(&self) -> String {
        if !self.enabled {
            return "cpu_affinity off".into();
        }
        let uniq: std::collections::BTreeSet<&Vec<usize>> =
            self.rollout.iter().collect();
        format!(
            "cpu_affinity on: reserved cpus {:?}, {} rollout workers over {} cores",
            self.reserved,
            self.rollout.len(),
            uniq.len()
        )
    }
}

/// The native pool's CPU set, installed by [`PlacementPlan::install_pool_hint`].
static POOL_CPUS: OnceLock<Vec<usize>> = OnceLock::new();

/// Called by every native-pool worker as it starts: pin to the reserved
/// set if a plan installed one, else do nothing.
pub fn pin_native_pool_thread() {
    if let Some(cpus) = POOL_CPUS.get() {
        pin_current_thread(cpus);
    }
}

/// Pin the calling thread to `cpus` via `sched_setaffinity(0, ...)`.
/// Returns whether the kernel accepted the mask; `false` on unsupported
/// platforms (graceful no-op) or when the mask is empty.
pub fn pin_current_thread(cpus: &[usize]) -> bool {
    if cpus.is_empty() {
        return false;
    }
    let words = cpus.iter().max().unwrap() / 64 + 1;
    let mut mask = vec![0u64; words];
    for &c in cpus {
        mask[c / 64] |= 1u64 << (c % 64);
    }
    sched_setaffinity_self(&mask) == 0
}

/// Raw `sched_setaffinity(pid=0, len, mask)` — pid 0 means the calling
/// thread.  Returns the kernel's result (0 on success, negative errno
/// otherwise).  libc-free: the two syscall instructions are the whole
/// dependency.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn sched_setaffinity_self(mask: &[u64]) -> isize {
    let ret: isize;
    // SAFETY: sched_setaffinity (x86_64 nr 203) reads `len` bytes from the
    // `mask` pointer and mutates no user memory; `mask` is a live, aligned
    // allocation of exactly `mask.len() * 8` bytes for the duration of the
    // call.  rcx/r11 are declared clobbered as the syscall ABI requires.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret,
            in("rdi") 0usize,
            in("rsi") mask.len() * 8,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack, preserves_flags)
        );
    }
    ret
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn sched_setaffinity_self(mask: &[u64]) -> isize {
    let ret: isize;
    // SAFETY: sched_setaffinity (aarch64 nr 122) reads `len` bytes from
    // the `mask` pointer and mutates no user memory; `mask` is a live,
    // aligned allocation of exactly `mask.len() * 8` bytes for the
    // duration of the call.
    unsafe {
        core::arch::asm!(
            "svc 0",
            in("x8") 122usize,
            inlateout("x0") 0usize => ret,
            in("x1") mask.len() * 8,
            in("x2") mask.as_ptr(),
            options(nostack)
        );
    }
    ret
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn sched_setaffinity_self(_mask: &[u64]) -> isize {
    -1 // unsupported platform: report "not pinned", never fail the run
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_list_parses_kernel_syntax() {
        assert_eq!(parse_cpu_list("0-3,8").unwrap(), vec![0, 1, 2, 3, 8]);
        assert_eq!(parse_cpu_list("5").unwrap(), vec![5]);
        assert_eq!(parse_cpu_list("0,0,1-2,2").unwrap(), vec![0, 1, 2]);
        assert_eq!(parse_cpu_list(" 1 , 3-4 ").unwrap(), vec![1, 3, 4]);
    }

    #[test]
    fn bad_cpu_lists_are_hard_errors() {
        for bad in ["", "a", "1-", "-3", "3-1", "1,,2", "0-3,x"] {
            assert!(parse_cpu_list(bad).is_err(), "'{bad}' should be rejected");
        }
    }

    #[test]
    fn plan_spreads_rollout_and_reserves_cores() {
        // 8 logical CPUs = 4 physical cores x 2 SMT, one package.
        let topo = Topology {
            cpus: (0..8)
                .map(|c| CpuInfo { cpu: c, core: c % 4, package: 0 })
                .collect(),
        };
        let plan = PlacementPlan::from_parts(&topo, None, 1, 6);
        assert!(plan.is_enabled());
        // Core 0 (cpus 0 and 4) is reserved.
        assert_eq!(plan.reserved, vec![0, 4]);
        // 6 workers round-robin over cores 1..4.
        assert_eq!(plan.rollout.len(), 6);
        assert_eq!(plan.rollout[0], plan.rollout[3]);
        assert_ne!(plan.rollout[0], plan.rollout[1]);
        for set in &plan.rollout {
            assert!(set.iter().all(|c| !plan.reserved.contains(c)));
        }
    }

    #[test]
    fn pin_override_restricts_universe() {
        let topo = Topology::flat(8);
        let plan = PlacementPlan::from_parts(&topo, Some(&[2, 3, 5]), 1, 4);
        assert_eq!(plan.reserved, vec![2]);
        for set in &plan.rollout {
            for c in set {
                assert!([3usize, 5].contains(c), "cpu {c} outside override");
            }
        }
        // Override naming no known CPU: plan degrades to disabled.
        let empty = PlacementPlan::from_parts(&topo, Some(&[99]), 1, 4);
        assert!(!empty.is_enabled());
    }

    #[test]
    fn single_core_machine_degrades_gracefully() {
        let topo = Topology::flat(1);
        let plan = PlacementPlan::from_parts(&topo, None, 2, 4);
        assert!(plan.is_enabled());
        assert_eq!(plan.reserved, vec![0]);
        assert_eq!(plan.rollout.len(), 4);
        for set in &plan.rollout {
            assert_eq!(set, &vec![0]);
        }
    }

    #[test]
    fn two_package_plan_prefers_reserved_package() {
        // 2 packages x 2 cores, no SMT.
        let topo = Topology {
            cpus: (0..4)
                .map(|c| CpuInfo { cpu: c, core: c % 2, package: c / 2 })
                .collect(),
        };
        let plan = PlacementPlan::from_parts(&topo, None, 1, 3);
        // Reserved = package 0 core 0; first rollout core should be the
        // remaining package-0 core (cpu 1), before package 1.
        assert_eq!(plan.reserved, vec![0]);
        assert_eq!(plan.rollout[0], vec![1]);
    }

    #[test]
    fn disabled_plan_is_inert() {
        let plan = PlacementPlan::disabled();
        assert!(!plan.is_enabled());
        plan.pin_reserved(); // must not panic on empty sets
        plan.install_pool_hint();
        assert_eq!(plan.describe(), "cpu_affinity off");
    }

    #[test]
    fn pinning_self_to_all_cpus_is_accepted_on_linux() {
        // Pin to the full online set: behavior-neutral, but exercises the
        // raw syscall path end to end where it exists.
        let topo = Topology::detect();
        let all: Vec<usize> = topo.cpus.iter().map(|c| c.cpu).collect();
        let ok = pin_current_thread(&all);
        if cfg!(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        )) {
            assert!(ok, "sched_setaffinity to the full online set failed");
        } else {
            assert!(!ok);
        }
    }

    #[test]
    fn empty_mask_is_rejected_cheaply() {
        assert!(!pin_current_thread(&[]));
    }
}
