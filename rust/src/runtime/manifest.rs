//! The AOT contract: `artifacts/<spec>/manifest.json`, written by
//! `python/compile/aot.py` and parsed here.  Every shape/ordering the Rust
//! side relies on is checked against this file at startup, so a stale
//! artifacts directory fails fast instead of feeding garbage to PJRT.

use anyhow::{anyhow, Context, Result};
use std::path::Path;

use crate::json::Json;

#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    /// (H, W, C)
    pub obs_shape: [usize; 3],
    pub action_heads: Vec<usize>,
    pub hidden: usize,
    pub policy_batch: usize,
    pub train_batch: usize,
    pub rollout: usize,
    pub params: Vec<ParamDef>,
    pub n_params: usize,
    pub hyper_names: Vec<String>,
    pub hypers_default: Vec<f32>,
    pub metric_names: Vec<String>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        let req_usize = |k: &str| -> Result<usize> {
            j.req(k)
                .map_err(|e| anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow!("field '{k}' is not a number"))
        };
        let obs = j
            .req("obs_shape")
            .map_err(|e| anyhow!("{e}"))?
            .usize_arr()
            .ok_or_else(|| anyhow!("obs_shape malformed"))?;
        if obs.len() != 3 {
            return Err(anyhow!("obs_shape must have 3 dims, got {obs:?}"));
        }
        let params_json = j
            .req("params")
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("params malformed"))?;
        let mut params = Vec::with_capacity(params_json.len());
        for p in params_json {
            let name = p
                .req("name")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("param name malformed"))?
                .to_string();
            let shape = p
                .req("shape")
                .map_err(|e| anyhow!("{e}"))?
                .usize_arr()
                .ok_or_else(|| anyhow!("param shape malformed"))?;
            params.push(ParamDef { name, shape });
        }
        let man = Manifest {
            name: j
                .req("name")
                .map_err(|e| anyhow!("{e}"))?
                .as_str()
                .ok_or_else(|| anyhow!("name malformed"))?
                .to_string(),
            obs_shape: [obs[0], obs[1], obs[2]],
            action_heads: j
                .req("action_heads")
                .map_err(|e| anyhow!("{e}"))?
                .usize_arr()
                .ok_or_else(|| anyhow!("action_heads malformed"))?,
            hidden: req_usize("hidden")?,
            policy_batch: req_usize("policy_batch")?,
            train_batch: req_usize("train_batch")?,
            rollout: req_usize("rollout")?,
            n_params: req_usize("n_params")?,
            hyper_names: j
                .req("hyper_names")
                .map_err(|e| anyhow!("{e}"))?
                .str_arr()
                .ok_or_else(|| anyhow!("hyper_names malformed"))?,
            hypers_default: j
                .req("hypers_default")
                .map_err(|e| anyhow!("{e}"))?
                .f32_arr()
                .ok_or_else(|| anyhow!("hypers_default malformed"))?,
            metric_names: j
                .req("metric_names")
                .map_err(|e| anyhow!("{e}"))?
                .str_arr()
                .ok_or_else(|| anyhow!("metric_names malformed"))?,
            params,
        };
        if man.params.len() != man.n_params {
            return Err(anyhow!(
                "n_params {} != params list length {}",
                man.n_params,
                man.params.len()
            ));
        }
        if man.hyper_names.len() != man.hypers_default.len() {
            return Err(anyhow!("hyper names/defaults length mismatch"));
        }
        Ok(man)
    }

    pub fn obs_len(&self) -> usize {
        self.obs_shape.iter().product()
    }

    pub fn total_actions(&self) -> usize {
        self.action_heads.iter().sum()
    }

    pub fn n_heads(&self) -> usize {
        self.action_heads.len()
    }

    /// Index of a hyperparameter by name.
    pub fn hyper_index(&self, name: &str) -> Option<usize> {
        self.hyper_names.iter().position(|n| n == name)
    }

    /// Default hypers with overrides applied.
    pub fn hypers_with(
        &self,
        overrides: &std::collections::BTreeMap<String, f32>,
    ) -> Result<Vec<f32>> {
        let mut h = self.hypers_default.clone();
        for (k, v) in overrides {
            let i = self
                .hyper_index(k)
                .ok_or_else(|| anyhow!("unknown hyperparameter '{k}'"))?;
            h[i] = *v;
        }
        Ok(h)
    }

    /// Index of a metric by name.
    pub fn metric_index(&self, name: &str) -> Option<usize> {
        self.metric_names.iter().position(|n| n == name)
    }

    /// Total parameter count (for logs).
    pub fn total_param_elems(&self) -> usize {
        self.params
            .iter()
            .map(|p| p.shape.iter().product::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "name": "tiny", "obs_shape": [24, 32, 3], "action_heads": [3, 2],
        "hidden": 32, "fc_dim": 32, "policy_batch": 8, "train_batch": 4,
        "rollout": 8,
        "params": [
            {"name": "conv0/w", "shape": [4,4,3,8], "dtype": "f32"},
            {"name": "conv0/b", "shape": [8], "dtype": "f32"}
        ],
        "n_params": 2,
        "hyper_names": ["lr", "ent_coef"],
        "hypers_default": [0.0001, 0.003],
        "metric_names": ["total_loss"],
        "programs": {}
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.obs_len(), 24 * 32 * 3);
        assert_eq!(m.total_actions(), 5);
        assert_eq!(m.params[0].shape, vec![4, 4, 3, 8]);
        assert_eq!(m.total_param_elems(), 4 * 4 * 3 * 8 + 8);
        assert_eq!(m.hyper_index("ent_coef"), Some(1));
        assert_eq!(m.hyper_index("nope"), None);
    }

    #[test]
    fn hypers_with_overrides() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let mut o = std::collections::BTreeMap::new();
        o.insert("lr".to_string(), 0.5f32);
        let h = m.hypers_with(&o).unwrap();
        assert_eq!(h, vec![0.5, 0.003]);
        o.insert("bogus".to_string(), 1.0);
        assert!(m.hypers_with(&o).is_err());
    }

    #[test]
    fn rejects_inconsistent_counts() {
        let bad = SAMPLE.replace("\"n_params\": 2", "\"n_params\": 3");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        let path = Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/artifacts/tiny/manifest.json"
        ));
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert_eq!(m.name, "tiny");
            assert_eq!(m.action_heads, vec![3, 2]);
            assert_eq!(m.rollout, 8);
            assert!(m.total_param_elems() > 10_000);
        }
    }
}
