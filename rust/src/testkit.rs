//! Minimal property-based testing harness (offline stand-in for proptest).
//!
//! `check(cases, |g| { ... })` runs a closure against `cases` randomly
//! generated inputs drawn through the [`Gen`] handle.  On failure it reruns
//! with the same seed to confirm, then panics with the seed so the case is
//! reproducible (`SF_TESTKIT_SEED=<seed>` pins the whole run).
//!
//! Used by the coordinator/env/ipc property suites (routing invariants,
//! batching invariants, slot-reuse safety, env determinism...).

use crate::util::Rng;

/// Randomness handle passed to properties.
pub struct Gen {
    rng: Rng,
    /// Seed of the current case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.below(hi - lo + 1)
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_u8(&mut self, len: usize) -> Vec<u8> {
        (0..len).map(|_| (self.rng.next_u64() & 0xff) as u8).collect()
    }

    /// Borrow the raw RNG (for shuffles etc.).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Iteration budget for stress tests: `SF_STRESS_ITERS` overrides
/// `default` when set.  The sanitizer CI lanes (Miri, TSan) run the same
/// suites with this dialed way down — instrumentation slows each step by
/// 10-100x, and the coverage those tools add comes from *observing* the
/// synchronization, not from raw iteration counts.
pub fn stress_iters(default: usize) -> usize {
    match std::env::var("SF_STRESS_ITERS") {
        Ok(s) => s.trim().parse().expect("SF_STRESS_ITERS must be a usize"),
        Err(_) => default,
    }
}

fn root_seed() -> u64 {
    match std::env::var("SF_TESTKIT_SEED") {
        Ok(s) => s.parse().expect("SF_TESTKIT_SEED must be u64"),
        Err(_) => 0x5afe_fac7_0123_4567,
    }
}

/// Run `prop` against `cases` random inputs.
pub fn check<F: FnMut(&mut Gen)>(cases: usize, mut prop: F) {
    let mut root = Rng::new(root_seed());
    for case in 0..cases {
        let case_seed = root.next_u64();
        let mut g = Gen { rng: Rng::new(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut g)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce the full run with SF_TESTKIT_SEED={}",
                root_seed()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(50, |g| {
            let n = g.usize_in(1, 100);
            let v = g.vec_f32(n, -1.0, 1.0);
            assert_eq!(v.len(), n);
            assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures_with_seed() {
        check(100, |g| {
            // Fails for roughly half the cases.
            assert!(g.bool(), "coin came up false");
        });
    }

    #[test]
    fn gen_is_deterministic_per_seed() {
        let mut a = Gen { rng: Rng::new(7), case_seed: 7 };
        let mut b = Gen { rng: Rng::new(7), case_seed: 7 };
        for _ in 0..32 {
            assert_eq!(a.u64(), b.u64());
        }
    }
}
