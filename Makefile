# Workflow wrappers.  `cargo build/test` need nothing beyond a Rust
# toolchain (native backend); `artifacts` is only for the pjrt backend and
# requires the python/ layer (jax).

.PHONY: artifacts test test-pjrt bench bench-json clippy clean

# Lower the JAX/Pallas programs to HLO text + manifest.json (pjrt backend).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

test:
	cargo test -q

# Compile-check the pjrt path too (executing it needs real xla-rs; see README).
test-pjrt:
	cargo test -q --features pjrt

bench:
	cargo bench

# Emit machine-readable perf records (BENCH_<name>.json at the repo root:
# frames/sec, p50/p95 batch latency, transport msgs/sec per producer count,
# learner assembly/train overlap, config) so the perf trajectory across
# PRs is recorded.  SF_BENCH_FRAMES scales the per-cell budget.
bench-json:
	cargo run --release --bin repro -- bench throughput --frames $(or $(SF_BENCH_FRAMES),20000)
	cargo run --release --bin repro -- bench fifo --frames 50000
	cargo run --release --bin repro -- bench scenarios --frames $(or $(SF_BENCH_FRAMES),5000)

clippy:
	cargo clippy --all-targets -- -D warnings \
		-A clippy::too_many_arguments -A clippy::needless_range_loop \
		-A clippy::manual_div_ceil

clean:
	cargo clean
	rm -rf artifacts
