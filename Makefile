# Workflow wrappers.  `cargo build/test` need nothing beyond a Rust
# toolchain (native backend); `artifacts` is only for the pjrt backend and
# requires the python/ layer (jax); `miri`/`tsan` need a nightly toolchain
# with the miri / rust-src components.

.PHONY: artifacts test test-pjrt bench bench-json clippy clean \
	chaos miri tsan lint

# Lower the JAX/Pallas programs to HLO text + manifest.json (pjrt backend).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

test:
	cargo test -q

# Compile-check the pjrt path too (executing it needs real xla-rs; see README).
test-pjrt:
	cargo test -q --features pjrt

bench:
	cargo bench

# Emit machine-readable perf records (BENCH_<name>.json at the repo root:
# frames/sec, p50/p95 batch latency, transport msgs/sec per producer count,
# learner assembly/train overlap, config) so the perf trajectory across
# PRs is recorded.  SF_BENCH_FRAMES scales the per-cell budget.
bench-json:
	cargo run --release --bin repro -- bench throughput --frames $(or $(SF_BENCH_FRAMES),20000)
	cargo run --release --bin repro -- bench fifo --frames 50000
	cargo run --release --bin repro -- bench scenarios --frames $(or $(SF_BENCH_FRAMES),5000)
	cargo run --release --bin repro -- bench envs --frames $(or $(SF_BENCH_FRAMES),20000)
	cargo run --release --bin repro -- bench pin --frames $(or $(SF_BENCH_FRAMES),20000)
	cargo run --release --bin repro -- bench obs --frames $(or $(SF_BENCH_FRAMES),30000)

clippy:
	cargo clippy --all-targets -- -D warnings

# Deterministic interleaving model checker over the lock-free transport
# (rust/src/util/chaos.rs): the whole suite under the instrumented
# `crate::sync` facade, plus the transport models in chaos_transport.rs.
chaos:
	cargo test -q --features chaos

# Miri: UB detection (uninit reads, aliasing, leaks) over the ipc/pool
# unit tests.  `cfg!(miri)` dials iteration counts down in-tree.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" \
		cargo +nightly miri test --lib ipc:: runtime::native::pool

# ThreadSanitizer over the transport stress suite and the batched-render
# property tests (the render pool shards frames across threads): catches
# real weak-memory races the serialized model checker cannot (stale reads
# from the store buffer).  Needs nightly + the rust-src component.
tsan:
	RUSTFLAGS="-Zsanitizer=thread" SF_STRESS_ITERS=500 \
	TSAN_OPTIONS="halt_on_error=1" \
		cargo +nightly test -Zbuild-std \
		--target x86_64-unknown-linux-gnu \
		--test prop_transport --test prop_env_batch

# In-tree static-analysis gate: SAFETY comments on every unsafe block,
# no std::sync/std::thread bypasses of the crate::sync facade in the
# concurrency modules, no bare Instant::now() in coordinator//ipc/ (use
# crate::obs::clock), no blanket -A clippy downgrades in CI configs.
lint:
	cargo run --release --bin sf_lint

clean:
	cargo clean
	rm -rf artifacts
